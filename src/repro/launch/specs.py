"""ShapeDtypeStruct input specs + sharding assignment for every entry point.

``input_specs(arch, shape_name, mesh, ...)`` returns (entry_fn, args) where
every arg leaf is a ``jax.ShapeDtypeStruct`` carrying a ``NamedSharding`` —
the shannon/kernels pattern: weak-type-correct, shardable, and *allocation
free*, so 30B-param configs lower on a CPU host.

Entry kinds per input shape (base.INPUT_SHAPES):
  train_4k     -> fl_round   (K local steps + 3SFC uplink, clients = pod·data)
  prefill_32k  -> prefill
  decode_32k   -> decode_step (1 token against a seq_len cache)
  long_500k    -> decode_step (sub-quadratic archs; dense/moe use the
                  sliding-window serving variant, see DESIGN.md §5)
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import (CompressorConfig, FLConfig, INPUT_SHAPES,
                                ModelConfig, ShapeConfig, get_config)
from repro.configs.run import RunConfig
from repro.core.strategy import make_strategy
from repro.fl.round import FLState, build_fl_round
from repro.launch import mesh as mesh_lib
from repro.models import params as params_lib
from repro.models.build import ENC_SYN_LEN, build_model, syn_loss_fn, syn_spec_for
from repro.models.encdec import EncDec

PyTree = Any

# serving window for long_500k on full-attention archs (DESIGN.md §5)
LONG_CTX_WINDOW = 8192
# archs whose defining op is full cross-attention at short length: skip 500k
LONG_CTX_SKIP = ("seamless-m4t-medium",)


def _div(n: int, m: int) -> bool:
    return m > 0 and n % m == 0


def _sds(shape, dtype, mesh, spec) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def _with_sharding(tree_shapes: PyTree, spec_tree: PyTree, mesh) -> PyTree:
    return jax.tree_util.tree_map(
        lambda sd, sp: jax.ShapeDtypeStruct(sd.shape, sd.dtype,
                                            sharding=NamedSharding(mesh, sp)),
        tree_shapes, spec_tree)


def param_specs(model, mesh, client_axis=None) -> PyTree:
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    specs = params_lib.sharding_specs(shapes, mesh, client_axis=client_axis)
    return _with_sharding(shapes, specs, mesh)


# ---------------------------------------------------------------------------
# cache sharding rules (path-based, mirrors models.*.init_cache structures)
# ---------------------------------------------------------------------------


def cache_specs(cfg: ModelConfig, cache_shapes: PyTree, mesh) -> PyTree:
    """Sharding for decode caches: batch -> 'data'(+'pod'); heads/width -> 'model'."""
    msize = mesh_lib.axis_size(mesh, "model")
    caxes = mesh_lib.client_axes(mesh)
    dsize = mesh_lib.axis_size(mesh, "data") * mesh_lib.axis_size(mesh, "pod")
    batch_spec = caxes if len(caxes) > 1 else "data"

    def _bspec(n):
        return batch_spec if _div(n, dsize) else None

    def spec_for(path, leaf):
        name = ""
        for q in path:
            if isinstance(q, jax.tree_util.GetAttrKey):
                name = q.name
            elif isinstance(q, jax.tree_util.DictKey):
                name = str(q.key)
        shape = leaf.shape
        # leading (layers,) axis present on stacked caches (rank sniffing is
        # safe here: every cache family is handled by field name)
        def b(i):   # batch axis index: 1 if stacked, else 0
            return i
        if name in ("k", "v"):
            # (L, B, len, KV, hd) or (B, len, KV, hd)
            off = len(shape) - 4
            spec = [None] * len(shape)
            spec[off] = _bspec(shape[off])
            if _div(shape[off + 2], msize):
                spec[off + 2] = "model"
            elif _div(shape[off + 3], msize):
                spec[off + 3] = "model"
            return P(*spec)
        if name == "pos":
            off = len(shape) - 2
            spec = [None] * len(shape)
            spec[off] = _bspec(shape[off])
            return P(*spec)
        if name == "conv_buf":
            # (..., B, width-1, C)
            off = len(shape) - 3
            spec = [None] * len(shape)
            spec[off] = _bspec(shape[off])
            if _div(shape[-1], msize):
                spec[-1] = "model"
            return P(*spec)
        if name == "state":
            # (..., B, H, P, N)
            off = len(shape) - 4
            spec = [None] * len(shape)
            spec[off] = _bspec(shape[off])
            if _div(shape[off + 1], msize):
                spec[off + 1] = "model"
            return P(*spec)
        if name == "h":
            # (..., B, W)
            spec = [None] * len(shape)
            spec[-2] = _bspec(shape[-2])
            if _div(shape[-1], msize):
                spec[-1] = "model"
            return P(*spec)
        return P()

    specs = jax.tree_util.tree_map_with_path(spec_for, cache_shapes)
    return _with_sharding(cache_shapes, specs, mesh)


# ---------------------------------------------------------------------------
# per-arch shape adjustments
# ---------------------------------------------------------------------------


def serving_config(cfg: ModelConfig, shape: ShapeConfig) -> Optional[ModelConfig]:
    """Arch variant used for this input shape; None => skipped pair."""
    if shape.name == "long_500k":
        if cfg.name in LONG_CTX_SKIP:
            return None
        if cfg.family in ("ssm",):
            return cfg                       # natively O(1) state
        if cfg.attn_window:
            return cfg                       # hybrid local attention
        return cfg.replace(attn_window=LONG_CTX_WINDOW)   # SWA serving variant
    return cfg


def _batch_specs(cfg: ModelConfig, mesh, shapes: Dict[str, Tuple], dtypes) -> Dict:
    """Shard the leading batch axis of every input over 'data' (+'pod')."""
    caxes = mesh_lib.client_axes(mesh)
    dspec = caxes if len(caxes) > 1 else "data"
    out = {}
    for k, shp in shapes.items():
        nbatch = shp[0]
        total = mesh_lib.axis_size(mesh, "data") * mesh_lib.axis_size(mesh, "pod")
        spec = [dspec if _div(nbatch, total) else None] + [None] * (len(shp) - 1)
        out[k] = _sds(shp, dtypes[k], mesh, P(*spec))
    return out


# ---------------------------------------------------------------------------
# entry builders
# ---------------------------------------------------------------------------


def make_train_entry(cfg: ModelConfig, shape: ShapeConfig, mesh,
                     fl: Optional[FLConfig] = None, *,
                     fused_decode: bool = False,
                     ef_dtype=jnp.float32,
                     client_parallel: str = "vmap"):
    """fl_round over clients = pod*data. Returns (fn, args_pytree).

    §Perf variants: ``fused_decode`` swaps the full-gradient client-axis
    all-reduce for an all-gather of the tiny 3SFC payloads (fl/round.py);
    ``ef_dtype`` stores the per-client EF residual in reduced precision;
    ``client_parallel='shard_map'`` lowers the explicitly sharded client
    fan-out instead of the GSPMD-partitioned vmap.
    """
    num_clients = mesh_lib.num_clients_for(mesh)
    caxes = mesh_lib.client_axes(mesh)
    cspec = caxes if len(caxes) > 1 else "data"
    per_client = max(1, shape.global_batch // num_clients)
    fl = fl or FLConfig(num_clients=num_clients, local_steps=1, local_lr=0.01,
                        compressor=CompressorConfig(kind="threesfc", syn_seq=16,
                                                    soft_label_rank=8))
    import dataclasses as _dc
    fl = _dc.replace(fl, num_clients=num_clients)
    model = build_model(cfg)
    sspec = syn_spec_for(cfg, fl.compressor)
    strategy = make_strategy(fl.compressor, loss_fn=syn_loss_fn(model),
                             syn_spec=sspec, local_lr=fl.local_lr)
    # microbatching keeps per-step live activations ~1 sequence deep
    num_micro = min(per_client, 8) if shape.seq_len >= 4096 else 1
    while per_client % num_micro:
        num_micro -= 1
    run = RunConfig(fl=fl, client_parallel=client_parallel,
                    fused_decode=fused_decode, num_micro=num_micro,
                    mesh=mesh)
    round_fn = build_fl_round(model.loss, strategy, run)

    K, B, S = fl.local_steps, per_client, shape.seq_len
    pspecs = param_specs(model, mesh)
    ef_shapes = jax.tree_util.tree_map(
        lambda sd: jax.ShapeDtypeStruct((num_clients, *sd.shape), ef_dtype), pspecs)
    ef_specs = _with_sharding(
        ef_shapes, params_lib.sharding_specs(ef_shapes, mesh, client_axis=caxes),
        mesh)
    state = FLState(params=pspecs, ef=ef_specs,
                    round=_sds((), jnp.int32, mesh, P()))

    batch: Dict[str, jax.ShapeDtypeStruct] = {
        "tokens": _sds((num_clients, K, B, S), jnp.int32, mesh, P(cspec))}
    if isinstance(model, EncDec):
        batch["frames"] = _sds((num_clients, K, B, cfg.num_mm_tokens, cfg.d_model),
                               jnp.bfloat16, mesh, P(cspec))
    elif cfg.num_mm_tokens:
        batch["prefix_embeds"] = _sds(
            (num_clients, K, B, cfg.num_mm_tokens, cfg.d_model),
            jnp.bfloat16, mesh, P(cspec))
    key = _sds((2,), jnp.uint32, mesh, P())

    def entry(state, batch, key):
        return round_fn(state, batch, key)

    return entry, (state, batch, key)


def make_prefill_entry(cfg: ModelConfig, shape: ShapeConfig, mesh):
    model = build_model(cfg)
    B, S = shape.global_batch, shape.seq_len
    dsize = mesh_lib.axis_size(mesh, "data") * mesh_lib.axis_size(mesh, "pod")
    caxes = mesh_lib.client_axes(mesh)
    bspec = (caxes if len(caxes) > 1 else "data") if _div(B, dsize) else None
    tokens = _sds((B, S), jnp.int32, mesh, P(bspec))
    pspecs = param_specs(model, mesh)

    if isinstance(model, EncDec):
        frames = _sds((B, cfg.num_mm_tokens, cfg.d_model), jnp.bfloat16, mesh,
                      P(bspec))

        def entry(params, frames, tokens):
            return model.prefill(params, frames, tokens, cache_len=S)

        return entry, (pspecs, frames, tokens)

    if cfg.num_mm_tokens:
        prefix = _sds((B, cfg.num_mm_tokens, cfg.d_model), jnp.bfloat16, mesh,
                      P(bspec))

        def entry(params, prefix, tokens):
            return model.prefill(params, tokens, cache_len=S, prefix_embeds=prefix)

        return entry, (pspecs, prefix, tokens)

    def entry(params, tokens):
        return model.prefill(params, tokens, cache_len=S)

    return entry, (pspecs, tokens)


def make_decode_entry(cfg: ModelConfig, shape: ShapeConfig, mesh):
    """One-token decode against a seq_len-deep cache."""
    model = build_model(cfg)
    B, S = shape.global_batch, shape.seq_len
    dsize = mesh_lib.axis_size(mesh, "data") * mesh_lib.axis_size(mesh, "pod")
    caxes = mesh_lib.client_axes(mesh)
    bspec = (caxes if len(caxes) > 1 else "data") if _div(B, dsize) else None
    pspecs = param_specs(model, mesh)
    if isinstance(model, EncDec):
        cache_shapes = jax.eval_shape(
            functools.partial(model.init_cache, B, S, cfg.num_mm_tokens))
    else:
        cache_shapes = jax.eval_shape(functools.partial(model.init_cache, B, S))
    cspecs = cache_specs(cfg, cache_shapes, mesh)
    # decode batch sharding: force the cache batch axis onto 'data' too
    token = _sds((B,), jnp.int32, mesh, P(bspec))
    t = _sds((), jnp.int32, mesh, P())

    def entry(params, cache, token, t):
        return model.decode_step(params, cache, token, t)

    return entry, (pspecs, cspecs, token, t)


def make_entry(arch: str, shape_name: str, mesh, fl: Optional[FLConfig] = None,
               *, variant: Optional[Dict] = None):
    """(entry_fn, args) for one (arch x input-shape) pair; None if skipped.

    ``variant`` (§Perf knobs): {"fused_decode": bool, "ef_dtype": "bfloat16",
    "param_dtype": "bfloat16", "act_shard": bool, "local_steps": int,
    "client_parallel": "vmap" | "shard_map"}.
    """
    variant = variant or {}
    shape = INPUT_SHAPES[shape_name]
    cfg = serving_config(get_config(arch), shape)
    if cfg is None:
        return None
    if variant.get("param_dtype"):
        cfg = cfg.replace(param_dtype=variant["param_dtype"])
    if variant.get("act_shard"):
        from repro.models import shard
        shard.enable(True, mesh)
    if variant.get("no_qk_hd_shard"):
        params_lib.set_qk_hd_fallback(False)
    if shape.mode == "train":
        fl2 = fl
        if variant.get("local_steps"):
            import dataclasses as _dc
            fl2 = _dc.replace(
                fl or FLConfig(local_steps=1,
                               compressor=CompressorConfig(
                                   kind="threesfc", syn_seq=16,
                                   soft_label_rank=8)),
                local_steps=variant["local_steps"])
        ef_dtype = {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[
            variant.get("ef_dtype", "float32")]
        return make_train_entry(
            cfg, shape, mesh, fl2,
            fused_decode=variant.get("fused_decode", False),
            ef_dtype=ef_dtype,
            client_parallel=variant.get("client_parallel", "vmap"))
    if shape.mode == "prefill":
        return make_prefill_entry(cfg, shape, mesh)
    return make_decode_entry(cfg, shape, mesh)
