import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: AOT-lower + compile every (arch x input-shape) pair on
the production mesh and harvest memory/cost/collective analyses.

The two XLA_FLAGS lines above MUST run before any other import (jax locks
the device count on first init) — that is why they sit above the docstring.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch tinyllama-1.1b \
        --shape train_4k [--multi-pod]
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]

Per pair the run writes experiments/dryrun/<arch>__<shape>__<mesh>.json with
memory_analysis, cost_analysis, collective bytes, and the roofline terms.
Failures (sharding mismatch, unsupported collective) are bugs in this repo's
sharding rules — they raise, they are not skipped.
"""
import argparse
import json
import time
import traceback
from typing import Optional

import jax

from repro.configs.base import ARCH_IDS, INPUT_SHAPES, get_config
from repro.launch import specs as specs_lib
from repro.launch.mesh import make_production_mesh
from repro.utils import roofline as rl

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def tokens_for(arch: str, shape_name: str) -> float:
    s = INPUT_SHAPES[shape_name]
    if s.mode == "train":
        return float(s.global_batch * s.seq_len)
    if s.mode == "prefill":
        return float(s.global_batch * s.seq_len)
    return float(s.global_batch)      # decode: one token per sequence


def run_pair(arch: str, shape_name: str, multi_pod: bool = False,
             save: bool = True, verbose: bool = True,
             variant: Optional[dict] = None, tag: str = "",
             mesh_shape: Optional[tuple] = None) -> Optional[dict]:
    if mesh_shape:                      # §Perf mesh reshape (e.g. (4, 64))
        mesh = jax.make_mesh(mesh_shape, ("data", "model"))
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    made = specs_lib.make_entry(arch, shape_name, mesh, variant=variant)
    if made is None:
        if verbose:
            print(f"SKIP {arch} x {shape_name} (documented skip, DESIGN.md §5)")
        return None
    entry, args = made
    t0 = time.time()
    with mesh:
        lowered = jax.jit(entry).lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    hlo = compiled.as_text()
    shape = INPUT_SHAPES[shape_name]
    mode = "train" if shape.mode == "train" else "serve"
    cfg = specs_lib.serving_config(get_config(arch), shape)
    mf = rl.model_flops_estimate(cfg, tokens_for(arch, shape_name), mode)
    roof = rl.from_compiled(compiled, chips, mf, hlo_text=hlo)
    mesh_name = ("x".join(map(str, mesh_shape)) if mesh_shape
                 else ("2x16x16" if multi_pod else "16x16"))
    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "variant": variant or {},
        "tag": tag,
        "chips": chips,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        # CompiledMemoryStats is PER-DEVICE (verified empirically)
        "memory_per_dev": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", 0),
            "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes", 0),
        },
        # raw cost_analysis (NOTE: while bodies counted once — reference only)
        "xla_cost": {k: cost.get(k, 0.0) for k in
                     ("flops", "bytes accessed", "transcendentals")},
        "roofline": roof.as_dict(),
    }
    if verbose:
        args_gib = result["memory_per_dev"]["argument_bytes"] / 2**30
        peak_gib = result["memory_per_dev"]["peak_bytes"] / 2**30
        print(f"OK   {arch} x {shape_name} [{result['mesh']}]  "
              f"lower {t_lower:.0f}s compile {t_compile:.0f}s  "
              f"args/dev {args_gib:.2f} GiB peak/dev {peak_gib:.2f} GiB  "
              f"dominant={roof.dominant}  "
              f"C/M/X = {roof.compute_s:.3e}/{roof.memory_s:.3e}/"
              f"{roof.collective_s:.3e} s")
    if save:
        os.makedirs(OUT_DIR, exist_ok=True)
        suffix = f"__{tag}" if tag else ""
        fn = f"{arch}__{shape_name}__{result['mesh']}{suffix}"
        with open(os.path.join(OUT_DIR, fn + ".json"), "w") as f:
            json.dump(result, f, indent=2)
        # keep the per-device HLO so rooflines can be re-derived without
        # recompiling (analyzer iterations are free afterwards)
        import gzip
        with gzip.open(os.path.join(OUT_DIR, fn + ".hlo.gz"), "wt") as f:
            f.write(hlo)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(INPUT_SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--variant", type=str, default="",
                    help='JSON §Perf knobs, e.g. \'{"fused_decode": true}\'')
    ap.add_argument("--tag", type=str, default="")
    ap.add_argument("--mesh", type=str, default="",
                    help="override mesh shape, e.g. 4,64")
    args = ap.parse_args()
    variant = json.loads(args.variant) if args.variant else None
    mesh_shape = tuple(int(x) for x in args.mesh.split(",")) if args.mesh else None

    pairs = []
    if args.all:
        pairs = [(a, s) for a in ARCH_IDS for s in INPUT_SHAPES]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        pairs = [(args.arch, args.shape)]

    failures = []
    for arch, shape in pairs:
        mesh_name = "2x16x16" if args.multi_pod else "16x16"
        out = os.path.join(OUT_DIR, f"{arch}__{shape}__{mesh_name}.json")
        if args.skip_existing and os.path.exists(out):
            print(f"CACHED {arch} x {shape}")
            continue
        try:
            run_pair(arch, shape, multi_pod=args.multi_pod, variant=variant,
                     tag=args.tag, mesh_shape=mesh_shape)
        except Exception as e:                     # noqa: BLE001
            traceback.print_exc()
            failures.append((arch, shape, str(e)[:200]))
    if failures:
        print("\nFAILURES:")
        for f in failures:
            print(" ", f)
        raise SystemExit(1)
    print("\nall pairs lowered + compiled")


if __name__ == "__main__":
    main()
