"""Production mesh factory. Functions only — importing this module never
touches jax device state (jax locks the device count on first init, and the
dry-run needs to set XLA_FLAGS before that happens)."""
from __future__ import annotations

from typing import Tuple

import jax
from jax.sharding import PartitionSpec as P


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """TPU v5e pod slice: 16x16 = 256 chips per pod; 2 pods = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1) -> jax.sharding.Mesh:
    """Tiny mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    if model < 1 or n % model != 0:
        raise ValueError(
            f"make_host_mesh: {n} device(s) cannot be split into a "
            f"(data={n}//{model}, model={model}) mesh — n % model must be 0 "
            f"(a truncated mesh would silently drop devices)")
    return jax.make_mesh((n // model, model), ("data", "model"))


def client_axes(mesh: jax.sharding.Mesh) -> Tuple[str, ...]:
    """Mesh axes the FL client dimension is sharded over."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def num_clients_for(mesh: jax.sharding.Mesh) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n = sizes.get("data", 1) * sizes.get("pod", 1)
    return n


def axis_size(mesh: jax.sharding.Mesh, name: str) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return sizes.get(name, 1)
