"""Federated training driver — the end-to-end entry point.

Runs a real (executed, not dry-run) FL training job on whatever devices
exist: paper vision models by name, or a reduced LM-family arch. The
production-mesh path is exercised by dryrun.py; this driver is the
"train a ~100M model for a few hundred rounds" deliverable and writes
checkpoints + a metrics JSONL.

    PYTHONPATH=src python -m repro.launch.train --model mlp --dataset mnist \
        --compressor threesfc --rounds 200 --clients 10
    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
        --smoke --rounds 20          # reduced LM config, token data
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_checkpoint
from repro.configs.base import (ARCH_IDS, CompressorConfig, FLConfig,
                                get_smoke_config)
from repro.core import flat
from repro.core.compressor import make_compressor
from repro.data.partition import dirichlet_partition
from repro.data.synthetic import make_class_image_dataset, make_token_dataset
from repro.fl.round import fl_init, make_fl_round
from repro.models.build import build_model, syn_loss_fn, syn_spec_for, vision_syn_spec
from repro.models.cnn import accuracy, make_paper_model
from repro.models.encdec import EncDec


def _compressor_cfg(name: str, d: int, budget: float) -> CompressorConfig:
    if name == "fedavg":
        return CompressorConfig(kind="identity", error_feedback=False)
    if name == "dgc":
        return CompressorConfig(kind="topk", keep_ratio=max(budget / 2, 1) / d)
    if name == "signsgd":
        return CompressorConfig(kind="signsgd")
    if name == "stc":
        return CompressorConfig(kind="stc", keep_ratio=1 / 33)
    if name == "threesfc":
        return CompressorConfig(kind="threesfc", syn_steps=10, syn_lr=0.1)
    raise ValueError(name)


def train_vision(args):
    from benchmarks.fl_harness import DATASETS  # shared dataset specs
    spec = DATASETS[args.dataset]
    model = make_paper_model(args.model, spec)
    params = model.init(jax.random.PRNGKey(args.seed))
    d = flat.tree_size(params)
    budget = float(np.prod(spec.input_shape) + spec.num_classes + 1)
    comp = _compressor_cfg(args.compressor, d, budget)
    syn_spec = vision_syn_spec(spec, comp)
    compressor = make_compressor(comp, loss_fn=model.syn_loss, syn_spec=syn_spec,
                                 local_lr=args.lr)
    fl_cfg = FLConfig(num_clients=args.clients, local_steps=args.local_steps,
                      local_lr=args.lr, compressor=comp)
    round_fn = jax.jit(make_fl_round(model.loss, compressor, fl_cfg))

    key = jax.random.PRNGKey(args.seed)
    train = make_class_image_dataset(key, args.train_size, spec.input_shape,
                                     spec.num_classes)
    test = make_class_image_dataset(jax.random.fold_in(key, 1), 1000,
                                    spec.input_shape, spec.num_classes)
    parts = dirichlet_partition(train.y, args.clients, alpha=args.alpha,
                                seed=args.seed, min_per_client=args.batch)
    state = fl_init(params, args.clients)

    @jax.jit
    def eval_acc(p):
        return accuracy(model.apply(p, jnp.asarray(test.x)), jnp.asarray(test.y))

    rng = np.random.default_rng(args.seed)
    os.makedirs(args.out, exist_ok=True)
    log = open(os.path.join(args.out, "metrics.jsonl"), "w")
    kr = jax.random.fold_in(key, 2)
    t0 = time.time()
    for r in range(args.rounds):
        bx = np.stack([train.x[rng.choice(p, (args.local_steps, args.batch))]
                       for p in parts])
        by = np.stack([train.y[rng.choice(p, (args.local_steps, args.batch))]
                       for p in parts])
        kr, kround = jax.random.split(kr)
        state, m = round_fn(state, {"x": jnp.asarray(bx), "y": jnp.asarray(by)},
                            kround)
        if (r + 1) % args.eval_every == 0 or r == args.rounds - 1:
            acc = float(eval_acc(state.params))
            rec = {"round": r + 1, "loss": float(m.loss), "acc": acc,
                   "cos": float(jnp.mean(m.cosine)),
                   "payload_floats": float(m.payload_floats),
                   "elapsed_s": round(time.time() - t0, 1)}
            print(json.dumps(rec))
            log.write(json.dumps(rec) + "\n")
            log.flush()
    save_checkpoint(os.path.join(args.out, "final"), state.params,
                    meta={"model": args.model, "dataset": args.dataset,
                          "compressor": args.compressor, "rounds": args.rounds})
    print(f"checkpoint -> {args.out}/final")


def train_lm_smoke(args):
    cfg = get_smoke_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    d = flat.tree_size(params)
    comp = CompressorConfig(kind=args.compressor if args.compressor != "fedavg"
                            else "identity",
                            error_feedback=args.compressor != "fedavg",
                            syn_steps=10, syn_lr=0.1, syn_seq=8)
    compressor = make_compressor(comp, loss_fn=syn_loss_fn(model),
                                 syn_spec=syn_spec_for(cfg, comp),
                                 local_lr=args.lr)
    fl_cfg = FLConfig(num_clients=args.clients, local_steps=args.local_steps,
                      local_lr=args.lr, compressor=comp)
    round_fn = jax.jit(make_fl_round(model.loss, compressor, fl_cfg))

    S = 64
    data = make_token_dataset(jax.random.PRNGKey(args.seed), 2048, S,
                              cfg.vocab_size)
    state = fl_init(params, args.clients)
    rng = np.random.default_rng(args.seed)
    kr = jax.random.PRNGKey(args.seed + 1)
    is_encdec = isinstance(model, EncDec)
    for r in range(args.rounds):
        idx = rng.integers(0, len(data), (args.clients, args.local_steps, args.batch))
        batch = {"tokens": jnp.asarray(data[idx])}
        if is_encdec:
            batch["frames"] = jnp.zeros(
                (args.clients, args.local_steps, args.batch,
                 cfg.num_mm_tokens, cfg.d_model), jnp.float32)
        elif cfg.num_mm_tokens:
            batch["prefix_embeds"] = jnp.zeros(
                (args.clients, args.local_steps, args.batch,
                 cfg.num_mm_tokens, cfg.d_model), jnp.float32)
        kr, kround = jax.random.split(kr)
        state, m = round_fn(state, batch, kround)
        if (r + 1) % args.eval_every == 0 or r == args.rounds - 1:
            print(json.dumps({"round": r + 1, "loss": float(m.loss),
                              "cos": float(jnp.mean(m.cosine)),
                              "params": d}))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="mlp",
                    choices=["mlp", "mnistnet", "convnet", "resnet", "regnet"])
    ap.add_argument("--dataset", default="mnist")
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced LM-family FL run (requires --arch)")
    ap.add_argument("--compressor", default="threesfc",
                    choices=["fedavg", "dgc", "signsgd", "stc", "threesfc"])
    ap.add_argument("--rounds", type=int, default=200)
    ap.add_argument("--clients", type=int, default=10)
    ap.add_argument("--local-steps", type=int, default=5, dest="local_steps")
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--lr", type=float, default=0.01)
    ap.add_argument("--alpha", type=float, default=0.5)
    ap.add_argument("--train-size", type=int, default=4000, dest="train_size")
    ap.add_argument("--eval-every", type=int, default=10, dest="eval_every")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="experiments/train_run")
    args = ap.parse_args()
    if args.arch and args.smoke:
        train_lm_smoke(args)
    else:
        train_vision(args)


if __name__ == "__main__":
    main()
