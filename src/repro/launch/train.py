"""Federated training driver — the end-to-end entry point.

Runs a real (executed, not dry-run) FL training job on whatever devices
exist: paper vision models by name, or a reduced LM-family arch. The
production-mesh path is exercised by dryrun.py; this driver is the
"train a ~100M model for a few hundred rounds" deliverable and writes
checkpoints + a metrics JSONL.

Both paths drive ``repro.fl.engine.RoundEngine``: data and Dirichlet pools
are device-resident, each eval block of ``--eval-every`` rounds is ONE
scanned dispatch with the EF state donated in place, and compressor budgets
come from the shared ``repro.fl.budget`` module (the same construction the
benchmarks use). The flags are folded into ONE validated
``repro.configs.run.RunConfig`` (logged as ``run_config.json`` next to the
metrics) and the round is built by ``repro.fl.round.build_fl_round`` over
the compressor's registered strategy; ``--wire codec`` ships framed uint8
buffers across the client/server boundary instead of float trees.

    PYTHONPATH=src python -m repro.launch.train --model mlp --dataset mnist \
        --compressor threesfc --rounds 200 --clients 10
    PYTHONPATH=src python -m repro.launch.train --model mlp --wire codec \
        --rounds 50 --clients 10     # measured serialized uplink bytes
    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
        --smoke --rounds 20          # reduced LM config, token data
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import (CheckpointManager, load_fl_checkpoint,
                              save_checkpoint, save_fl_checkpoint)
from repro.configs.base import ARCH_IDS, CompressorConfig, get_smoke_config
from repro.configs.run import RunConfig
from repro.core import flat
from repro.core.strategy import make_strategy
from repro.data.partition import dirichlet_partition
from repro.data.synthetic import make_class_image_dataset, make_token_dataset
from repro.fl.budget import matched_compressors
from repro.fl.engine import (RetryPolicy, RoundEngine, device_pools,
                             token_batcher, vision_batcher)
from repro.fl.round import build_fl_round
from repro.fl.sharding import make_fl_shardings
from repro.launch.mesh import make_host_mesh
from repro.models.build import build_model, syn_loss_fn, syn_spec_for, vision_syn_spec
from repro.models.cnn import DATASETS, accuracy, make_paper_model
from repro.models.encdec import EncDec
from repro.obs import (configure_tracer, get_registry, get_tracer,
                       merge_traces, write_chrome_trace)


class _ProfileWindow:
    """``jax.profiler`` capture over a round window ``[start, stop)``.

    Drive it with ``maybe_start(next_round)`` before rounds begin and
    ``after_round(completed_round)`` at round boundaries; ``close()``
    guarantees a started capture is stopped. On the socket transport the
    window is exact (the loop reports every round); on the in-process
    engine rounds live inside scanned blocks, so the window snaps to
    eval-block boundaries."""

    def __init__(self, out_dir: str, start: int, stop: int):
        self.dir, self.a, self.b = out_dir, start, stop
        self.on = False
        self.done = False

    def maybe_start(self, next_round: int) -> None:
        if self.done or self.on or not (self.a <= next_round < self.b):
            return
        os.makedirs(self.dir, exist_ok=True)
        jax.profiler.start_trace(self.dir)
        self.on = True

    def after_round(self, completed_round: int) -> None:
        nxt = completed_round + 1
        if self.on and nxt >= self.b:
            jax.profiler.stop_trace()
            self.on, self.done = False, True
        self.maybe_start(nxt)

    def close(self) -> None:
        if self.on:
            jax.profiler.stop_trace()
            self.on, self.done = False, True


def _make_profiler(args, r0: int):
    if not args.profile:
        return None
    if args.profile_window:
        a, b = (int(x) for x in args.profile_window.split(":", 1))
    else:
        a, b = r0, args.rounds
    return _ProfileWindow(args.profile, a, b)


def _dump_obs(out_dir: str, server=None) -> None:
    """End-of-run observability artifacts: ``meters.json`` always; when
    tracing is on, the merged span trace as ``trace.jsonl`` plus a
    Chrome/Perfetto ``trace.chrome.json`` (workers' piggybacked spans are
    shifted onto the server clock by the heartbeat offset estimates)."""
    tracer = get_tracer()
    if tracer.enabled:
        records = tracer.drain()
        if server is not None:
            records = merge_traces(records, server.pop_worker_spans(),
                                   server.clock_offsets())
        with open(os.path.join(out_dir, "trace.jsonl"), "w") as f:
            for r in records:
                f.write(json.dumps(r) + "\n")
        write_chrome_trace(records, os.path.join(out_dir, "trace.chrome.json"))
        print(f"trace -> {out_dir}/trace.jsonl ({len(records)} records, "
              f"{tracer.dropped} dropped)")
    with open(os.path.join(out_dir, "meters.json"), "w") as f:
        json.dump(get_registry().snapshot(), f, indent=1)


def make_fanout(args):
    """(client_parallel, mesh, shardings) from --client-parallel.

    'auto' picks the sharded fan-out when the host has multiple devices and
    the client count divides evenly over them, else the single-device vmap.
    Explicit 'shard_map' fails loudly (divisibility / single device) rather
    than silently degrading.
    """
    mode = args.client_parallel
    n = len(jax.devices())
    if mode == "auto":
        mode = "shard_map" if n > 1 and args.clients % n == 0 else "vmap"
    if mode == "vmap":
        return "vmap", None, None
    if n < 2:
        raise ValueError(
            "--client-parallel shard_map needs >1 device (a 1-shard "
            "shard_map would be vmap with extra steps); this host has "
            f"{n} — use 'vmap'/'auto' or force devices with "
            "XLA_FLAGS=--xla_force_host_platform_device_count=8")
    mesh = make_host_mesh()
    shardings = make_fl_shardings(mesh)
    shardings.check_divisible(args.clients)
    return "shard_map", mesh, shardings


def _write_run_config(out_dir: str, run: RunConfig) -> None:
    """Log the run's exact configuration next to its metrics."""
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "run_config.json"), "w") as f:
        json.dump(run.to_json(), f, indent=1)


def _ckpt_manager(args) -> CheckpointManager:
    """The run's checkpoint root: ``--resume PATH`` names an existing root
    to continue (new recovery points land in the same index); otherwise
    ``<out>/ckpt``."""
    return CheckpointManager(args.resume or os.path.join(args.out, "ckpt"))


def _check_resume_config(meta, run: RunConfig) -> None:
    """A resumed run must replay the checkpointed configuration — bitwise
    resume is only defined for the same (seed, fault_seed, knobs)."""
    want, got = run.to_json(), meta.get("run")
    if got is not None and got != want:
        diff = sorted(k for k in set(want) | set(got)
                      if want.get(k) != got.get(k))
        raise ValueError(
            f"--resume configuration mismatch on {diff}: the checkpoint was "
            f"written under a different RunConfig; rounds replayed from it "
            f"would not be the same run")


def _history_to_json(history):
    """Live-loop round records -> JSON-serializable checkpoint form."""
    return [{"round": int(rec["round"]),
             "wall_s": float(rec["wall_s"]),
             "participate": [bool(b) for b in rec["participate"]],
             "delivered": [bool(b) for b in rec["delivered"]],
             "retries": int(rec["retries"]),
             "bytes_up": int(rec["bytes_up"]),
             "bytes_down": int(rec["bytes_down"]),
             "overhead_up": int(rec.get("overhead_up", 0)),
             "overhead_down": int(rec.get("overhead_down", 0)),
             "dead": [int(c) for c in rec["dead"]],
             "losses": {str(k): float(v) for k, v in rec["losses"].items()}}
            for rec in history]


def _history_from_json(recs):
    return [{**rec,
             "participate": np.asarray(rec["participate"], bool),
             "delivered": np.asarray(rec["delivered"], bool),
             "losses": {int(k): float(v) for k, v in rec["losses"].items()}}
            for rec in recs]


def train_vision_socket(args, *, spec, model, params, strategy, run, codec):
    """The live multi-process path: a ``SocketServer`` + N spawned workers
    driven by ``repro.fl.engine.LiveRoundLoop`` — framed rounds over real
    sockets with the run's deadline/backoff/liveness knobs. Same metrics
    JSONL + checkpoint contract as the in-process path."""
    from repro.comm.transport import SocketServer, spawn_local_workers
    from repro.fl.engine import LiveRoundLoop
    from repro.launch.worker import vision_setup

    test = make_class_image_dataset(
        jax.random.fold_in(jax.random.PRNGKey(args.seed), 1), 1000,
        spec.input_shape, spec.num_classes)

    @jax.jit
    def eval_acc(p):
        return accuracy(model.apply(p, jnp.asarray(test.x)),
                        jnp.asarray(test.y))

    mgr = _ckpt_manager(args)
    r0, bank, history = 0, {}, []
    if args.resume:
        # full recovery point: params + per-client EF bank + ledger +
        # history; every worker is a (re)joiner the server re-syncs
        params, bank, meta = load_fl_checkpoint(mgr, params)
        _check_resume_config(meta, run)
        r0 = int(meta["round"])
        history = _history_from_json(meta.get("history", []))
        print(f"resuming from {mgr.path(r0)} at round {r0}")

    _write_run_config(args.out, run)
    t0 = time.time()
    server = SocketServer(args.clients,
                          heartbeat_s=run.heartbeat_s,
                          liveness_timeout_s=run.liveness_timeout_s)
    if args.resume:
        server.restore_ledger(meta["ledger"])  # round numbering continues
        server.seed_ef_bank(bank)
    procs = spawn_local_workers(server.address, range(args.clients))
    profiler = _make_profiler(args, r0)
    try:
        server.wait_ready()
        server.send_setup(vision_setup(run, model=args.model, spec=spec,
                                       train_size=args.train_size,
                                       trace=args.trace))
        mode = "a" if args.resume else "w"
        with open(os.path.join(args.out, "metrics.jsonl"), mode) as log:
            def on_round(rec, rep):
                if profiler is not None:
                    profiler.after_round(rec["round"])
                r = rec["round"] + 1
                if r % args.eval_every and r != args.rounds:
                    return
                out = {"round": r,
                       "loss": float(np.mean(list(rec["losses"].values())))
                       if rec["losses"] else None,
                       "acc": float(eval_acc(loop.params)),
                       "delivered": int(rec["delivered"].sum()),
                       "retries": rec["retries"],
                       "bytes_up": rec["bytes_up"],
                       "bytes_down": rec["bytes_down"],
                       "overhead_up": rec["overhead_up"],
                       "overhead_down": rec["overhead_down"],
                       "wall_s": round(rec["wall_s"], 4),
                       "elapsed_s": round(time.time() - t0, 1)}
                print(json.dumps(out))
                log.write(json.dumps(out) + "\n")
                log.flush()

            def ckpt_fn(lp, rnd):
                # settle: every participating live worker must have pushed
                # its round-``rnd`` commit before the bank is snapshotted —
                # an unsettled recovery point would not resume bitwise
                rec = lp.history[-1]
                cids = [c for c in range(args.clients)
                        if rec["participate"][c] and c not in rec["dead"]]
                if not server.wait_ef_bank(rnd, cids, timeout=30.0):
                    live = set(server.live_workers())
                    cids = [c for c in cids if c in live]
                    if not server.wait_ef_bank(rnd, cids, timeout=30.0):
                        raise RuntimeError(
                            f"EF bank did not settle for round {rnd}; "
                            f"refusing to write an unsettled recovery point")
                save_fl_checkpoint(
                    mgr, rnd + 1, lp.params, run=run,
                    ledger=server.ledger(),
                    history=_history_to_json(lp.history),
                    ef_bank=server.ef_bank(),
                    extra={"model": args.model, "dataset": args.dataset,
                           "compressor": args.compressor,
                           "transport": "socket"})

            loop = LiveRoundLoop(server, strategy, codec, run, params,
                                 on_round=on_round)
            loop.history.extend(history)
            ck = dict(ckpt_every=args.ckpt_every,
                      ckpt_fn=ckpt_fn if args.ckpt_every else None)
            # the first round jit-compiles the client step inside every
            # worker (round 0, or the first resumed round of freshly
            # restarted workers); a tight configured deadline would mark
            # them all undelivered before they ever ran. Boot patiently,
            # then enforce the configured deadline/backoff after that.
            remaining = args.rounds - r0
            boot = max(run.round_deadline_s, 300.0)
            if profiler is not None:
                profiler.maybe_start(r0)
            if remaining > 0:
                loop.run(1, deadline_s=boot,
                         policy=RetryPolicy(max_retries=0,
                                            recv_timeout_s=boot,
                                            max_timeout_s=boot), **ck)
                loop.run(remaining - 1, **ck)
            final = loop.params
            if args.ckpt_every and mgr.latest() != args.rounds:
                # final recovery point (cadence may not divide --rounds)
                ckpt_fn(loop, args.rounds - 1)
        _dump_obs(args.out, server=server)
    finally:
        if profiler is not None:
            profiler.close()
        server.stop()
        for p in procs:
            try:
                p.wait(timeout=5)
            except subprocess.TimeoutExpired:
                p.kill()
    save_checkpoint(os.path.join(args.out, "final"), final,
                    meta={"model": args.model, "dataset": args.dataset,
                          "compressor": args.compressor,
                          "rounds": args.rounds, "transport": "socket"})
    print(f"checkpoint -> {args.out}/final")


def train_vision(args):
    spec = DATASETS[args.dataset]
    model = make_paper_model(args.model, spec)
    params = model.init(jax.random.PRNGKey(args.seed))
    d = flat.tree_size(params)
    comp = matched_compressors(args.model, spec, d)[args.compressor]
    syn_spec = vision_syn_spec(spec, comp)
    strategy = make_strategy(comp, loss_fn=model.syn_loss, syn_spec=syn_spec,
                             local_lr=args.lr)
    if args.transport == "socket":
        # worker processes ARE the fan-out; the mesh paths stay in-process
        mode, mesh, shardings = "vmap", None, None
    else:
        mode, mesh, shardings = make_fanout(args)
    run = RunConfig.from_flags(args, compressor=comp, client_parallel=mode,
                               mesh=mesh)
    codec = strategy.wire_codec(params, policy=run.wire_policy) \
        if run.wire == "codec" else None
    if run.transport == "socket":
        return train_vision_socket(args, spec=spec, model=model,
                                   params=params, strategy=strategy,
                                   run=run, codec=codec)

    key = jax.random.PRNGKey(args.seed)
    train = make_class_image_dataset(key, args.train_size, spec.input_shape,
                                     spec.num_classes)
    test = make_class_image_dataset(jax.random.fold_in(key, 1), 1000,
                                    spec.input_shape, spec.num_classes)
    parts = dirichlet_partition(train.y, args.clients, alpha=args.alpha,
                                seed=args.seed, min_per_client=args.batch)
    pools = device_pools(parts)
    if shardings is not None:
        pools = shardings.place_pools(pools)
    engine = RoundEngine(
        build_fl_round(model.loss, strategy, run, codec=codec),
        vision_batcher(train.x, train.y, pools, args.local_steps, args.batch),
        seed=args.seed, shardings=shardings)
    state = engine.init_state(params, args.clients, strategy,
                              staleness_max=run.staleness_max)
    mgr = _ckpt_manager(args)
    meta_extra = {"model": args.model, "dataset": args.dataset,
                  "compressor": args.compressor, "transport": "inproc"}
    r0 = 0
    if args.resume:
        # the freshly-built state is the structure template: a checkpoint
        # of a different model/faults/staleness config fails typed here
        state, _, meta = load_fl_checkpoint(mgr, state)
        _check_resume_config(meta, run)
        if shardings is not None:
            state = shardings.place_state(state)
        r0 = int(meta["round"])
        print(f"resuming from {mgr.path(r0)} at round {r0}")

    @jax.jit
    def eval_acc(p):
        return accuracy(model.apply(p, jnp.asarray(test.x)), jnp.asarray(test.y))

    _write_run_config(args.out, run)
    t0 = time.time()
    profiler = _make_profiler(args, r0)
    if profiler is not None:
        profiler.maybe_start(r0)
    with open(os.path.join(args.out, "metrics.jsonl"),
              "a" if args.resume else "w") as log:
        def on_eval(st, m, r):
            if profiler is not None:
                profiler.after_round(r0 + r - 1)
            rec = {"round": r0 + r, "loss": float(m.loss[-1]),
                   "acc": float(eval_acc(st.params)),
                   "cos": float(np.mean(m.cosine[-1])),
                   "payload_floats": float(m.payload_floats[-1]),
                   "elapsed_s": round(time.time() - t0, 1)}
            print(json.dumps(rec))
            log.write(json.dumps(rec) + "\n")
            log.flush()

        def ckpt_fn(st, rnd):
            save_fl_checkpoint(mgr, rnd, st, run=run, extra=meta_extra)

        try:
            state, _ = engine.run(state, args.rounds - r0,
                                  eval_every=args.eval_every, eval_fn=on_eval,
                                  ckpt_every=args.ckpt_every,
                                  ckpt_fn=ckpt_fn if args.ckpt_every else None)
        finally:
            if profiler is not None:
                profiler.close()
    _dump_obs(args.out)
    if args.ckpt_every and mgr.latest() != args.rounds:
        save_fl_checkpoint(mgr, args.rounds, state, run=run, extra=meta_extra)
    save_checkpoint(os.path.join(args.out, "final"), state.params,
                    meta={"model": args.model, "dataset": args.dataset,
                          "compressor": args.compressor, "rounds": args.rounds})
    print(f"checkpoint -> {args.out}/final")


def train_lm_smoke(args):
    if getattr(args, "transport", "inproc") == "socket":
        raise ValueError(
            "--transport socket drives vision runs only: the worker rebuilds "
            "the client computation from the vision SETUP blob "
            "(repro.launch.worker); the LM smoke path is in-process")
    cfg = get_smoke_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    d = flat.tree_size(params)
    comp = CompressorConfig(kind=args.compressor if args.compressor != "fedavg"
                            else "identity",
                            error_feedback=args.compressor != "fedavg",
                            syn_steps=10, syn_lr=0.1, syn_seq=8)
    strategy = make_strategy(comp, loss_fn=syn_loss_fn(model),
                             syn_spec=syn_spec_for(cfg, comp),
                             local_lr=args.lr)
    mode, mesh, shardings = make_fanout(args)
    run = RunConfig.from_flags(args, compressor=comp, client_parallel=mode,
                               mesh=mesh)
    codec = strategy.wire_codec(params, policy=run.wire_policy) \
        if run.wire == "codec" else None

    S = 64
    data = make_token_dataset(jax.random.PRNGKey(args.seed), 2048, S,
                              cfg.vocab_size)
    extras = {}
    if isinstance(model, EncDec):
        extras["frames"] = (cfg.num_mm_tokens, cfg.d_model)
    elif cfg.num_mm_tokens:
        extras["prefix_embeds"] = (cfg.num_mm_tokens, cfg.d_model)
    engine = RoundEngine(
        build_fl_round(model.loss, strategy, run, codec=codec),
        token_batcher(data, args.clients, args.local_steps, args.batch,
                      extras=extras),
        seed=args.seed, shardings=shardings)
    state = engine.init_state(params, args.clients, strategy,
                              staleness_max=run.staleness_max)
    engine.run(state, args.rounds, eval_every=args.eval_every,
               eval_fn=lambda st, m, r: print(json.dumps(
                   {"round": r, "loss": float(m.loss[-1]),
                    "cos": float(np.mean(m.cosine[-1])), "params": d})))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="mlp",
                    choices=["mlp", "mnistnet", "convnet", "resnet", "regnet"])
    ap.add_argument("--dataset", default="mnist")
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced LM-family FL run (requires --arch)")
    ap.add_argument("--compressor", default="threesfc",
                    choices=["fedavg", "dgc", "signsgd", "stc", "threesfc"])
    ap.add_argument("--rounds", type=int, default=200)
    ap.add_argument("--clients", type=int, default=10)
    ap.add_argument("--local-steps", type=int, default=5, dest="local_steps")
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--lr", type=float, default=0.01)
    ap.add_argument("--alpha", type=float, default=0.5)
    ap.add_argument("--train-size", type=int, default=4000, dest="train_size")
    ap.add_argument("--eval-every", type=int, default=10, dest="eval_every")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="experiments/train_run")
    ap.add_argument("--client-parallel", default="auto", dest="client_parallel",
                    choices=["auto", "vmap", "shard_map"],
                    help="client fan-out: sharded over the host mesh "
                         "(shard_map) or single-program vmap")
    ap.add_argument("--wire", default="float", choices=["float", "codec"],
                    help="what crosses the client/server boundary: float "
                         "trees (accounted bytes) or the repro.comm codec's "
                         "framed uint8 buffers (measured bytes)")
    # fault model (repro.fl.faults): all default to the zero-fault config,
    # which compiles the exact unfaulted round
    ap.add_argument("--participation-rate", type=float, default=1.0,
                    dest="participation_rate",
                    help="fraction of clients scheduled each round")
    ap.add_argument("--drop-rate", type=float, default=0.0, dest="drop_rate",
                    help="probability a participating client's payload is "
                         "lost mid-round (EF banks the whole update)")
    ap.add_argument("--straggler-rate", type=float, default=0.0,
                    dest="straggler_rate",
                    help="probability a delivered payload arrives 1..k "
                         "rounds late (requires --staleness-max >= 1)")
    ap.add_argument("--staleness-max", type=int, default=0,
                    dest="staleness_max",
                    help="staleness bound k: late payloads are applied at "
                         "t+delay with weight 1/(1+delay); 0 disables the "
                         "ring buffer")
    ap.add_argument("--fault-seed", type=int, default=0, dest="fault_seed",
                    help="seed of the fault stream (schedules are a pure "
                         "function of (fault_seed, round))")
    # transport (repro.comm.transport): socket mode spawns N worker
    # processes and runs framed rounds over real sockets
    ap.add_argument("--transport", default="inproc",
                    choices=["inproc", "socket"],
                    help="how rounds move: one in-process program (the "
                         "engine's scanned loop) or a SocketServer + N "
                         "worker processes (requires --wire codec)")
    ap.add_argument("--round-deadline-s", type=float, default=30.0,
                    dest="round_deadline_s",
                    help="hard bound on one round's collect phase")
    ap.add_argument("--recv-timeout-s", type=float, default=2.0,
                    dest="recv_timeout_s",
                    help="per-client receive window before the first RESEND")
    ap.add_argument("--recv-backoff", type=float, default=2.0,
                    dest="recv_backoff",
                    help="exponential backoff factor per retry attempt")
    ap.add_argument("--transport-retries", type=int, default=2,
                    dest="transport_retries",
                    help="RESENDs before a client counts as dropped")
    ap.add_argument("--heartbeat-s", type=float, default=0.5,
                    dest="heartbeat_s", help="worker liveness tick period")
    ap.add_argument("--liveness-timeout-s", type=float, default=5.0,
                    dest="liveness_timeout_s",
                    help="silence window after which a worker counts as dead")
    # recovery (repro.checkpoint): periodic full-state recovery points +
    # bitwise resume — both transports
    ap.add_argument("--ckpt-every", type=int, default=0, dest="ckpt_every",
                    help="write a durable full-state recovery point every N "
                         "rounds (params + EF + staleness buffer + round "
                         "counter + byte ledger) under <out>/ckpt; 0 writes "
                         "only the final params checkpoint")
    ap.add_argument("--resume", default=None, metavar="CKPT_ROOT",
                    help="resume from the latest recovery point under this "
                         "checkpoint root (e.g. <out>/ckpt); the run must "
                         "use the same configuration, replays the remaining "
                         "rounds bitwise, and appends to the existing "
                         "metrics JSONL")
    # observability (repro.obs): host-side span tracing, metrics endpoints,
    # device-timeline profiling
    ap.add_argument("--trace", action="store_true",
                    help="record host-side spans (server round phases, "
                         "transport framing, checkpoint I/O; socket workers "
                         "piggyback theirs over MSG_METRIC) and write "
                         "<out>/trace.jsonl + trace.chrome.json")
    ap.add_argument("--profile", default=None, metavar="DIR",
                    help="capture a jax.profiler device trace into DIR "
                         "(view with TensorBoard or Perfetto)")
    ap.add_argument("--profile-window", default=None, metavar="A:B",
                    dest="profile_window",
                    help="restrict --profile to absolute rounds [A, B); "
                         "exact on --transport socket, snaps to eval-block "
                         "boundaries in-process")
    ap.add_argument("--metrics-port", type=int, default=None,
                    dest="metrics_port",
                    help="serve /healthz and /metrics (the obs.meters "
                         "snapshot) on this port for the run's duration "
                         "(0 picks a free port)")
    args = ap.parse_args(argv)
    if args.trace:
        configure_tracer(True, proc="server")
    http = None
    if args.metrics_port is not None:
        from repro.obs.http import ObsHTTPServer
        http = ObsHTTPServer(port=args.metrics_port)
        print(f"metrics -> {http.url}/metrics")
    try:
        if args.arch and args.smoke:
            train_lm_smoke(args)
        else:
            train_vision(args)
    finally:
        if http is not None:
            http.stop()


if __name__ == "__main__":
    main()
