"""Serving driver: batched prefill + decode loop on a reduced config.

Demonstrates the serving entry points actually executing (the production
32k/500k shapes are exercised AOT by dryrun.py):

    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
        --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.base import ARCH_IDS, get_smoke_config
from repro.models.build import build_model
from repro.models.encdec import EncDec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b", choices=ARCH_IDS)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32, dest="prompt_len")
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    model = build_model(cfg)
    # independent streams: reusing one key for init AND data correlates the
    # sampled prompt with the weights it is fed through
    k_init, k_tok, k_frames = jax.random.split(jax.random.PRNGKey(args.seed), 3)
    params = model.init(k_init)
    tokens = jax.random.randint(k_tok, (args.batch, args.prompt_len), 0,
                                cfg.vocab_size)
    cache_len = args.prompt_len + args.gen

    t0 = time.time()
    if isinstance(model, EncDec):
        frames = jax.random.normal(k_frames, (args.batch, cfg.num_mm_tokens,
                                              cfg.d_model))
        prefill = jax.jit(lambda p, f, t: model.prefill(p, f, t, cache_len))
        logits, cache, t = prefill(params, frames, tokens)
    else:
        prefill = jax.jit(lambda p, t: model.prefill(p, t, cache_len))
        logits, cache, t = prefill(params, tokens)
    print(f"prefill: batch={args.batch} len={args.prompt_len} "
          f"({time.time()-t0:.1f}s incl. compile)")

    decode = jax.jit(model.decode_step)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    out = [tok]
    t0 = time.time()
    for i in range(args.gen - 1):
        logits, cache = decode(params, cache, tok, t + i)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(tok)
    dt = time.time() - t0
    gen = jnp.stack(out, axis=1)
    print(f"decoded {args.gen} tokens x {args.batch} seqs in {dt:.2f}s "
          f"({args.gen*args.batch/max(dt,1e-9):.1f} tok/s incl. compile)")
    print("sample token ids:", gen[0, :12].tolist())
    assert bool(jnp.all(jnp.isfinite(logits))), "NaN logits"
    print("serve OK")


if __name__ == "__main__":
    main()
