"""Serving driver: batched prefill + decode loop on a reduced config.

Demonstrates the serving entry points actually executing (the production
32k/500k shapes are exercised AOT by dryrun.py):

    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
        --batch 4 --prompt-len 32 --gen 16

``--metrics-port`` keeps the process alive after the demo loop with
``/healthz`` and ``/metrics`` endpoints rendering the ``repro.obs``
registry snapshot — prefill/decode timings, token counters, and (once
this becomes the ingest tier of the roadmap's hierarchical aggregation)
worker liveness and byte ledgers, all through the same registry the FL
transports feed.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.base import ARCH_IDS, get_smoke_config
from repro.models.build import build_model
from repro.models.encdec import EncDec
from repro.obs import get_registry


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b", choices=ARCH_IDS)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32, dest="prompt_len")
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--metrics-port", type=int, default=None,
                    dest="metrics_port",
                    help="serve /healthz + /metrics (the obs.meters "
                         "snapshot) on this port and stay up after the "
                         "demo loop (0 picks a free port)")
    args = ap.parse_args(argv)

    meters = get_registry()
    http = None
    if args.metrics_port is not None:
        from repro.obs.http import ObsHTTPServer
        http = ObsHTTPServer(port=args.metrics_port)
        print(f"metrics -> {http.url}/metrics  health -> {http.url}/healthz")

    cfg = get_smoke_config(args.arch)
    model = build_model(cfg)
    meters.gauge("serve.batch").set(args.batch)
    meters.gauge("serve.prompt_len").set(args.prompt_len)
    # independent streams: reusing one key for init AND data correlates the
    # sampled prompt with the weights it is fed through
    k_init, k_tok, k_frames = jax.random.split(jax.random.PRNGKey(args.seed), 3)
    params = model.init(k_init)
    tokens = jax.random.randint(k_tok, (args.batch, args.prompt_len), 0,
                                cfg.vocab_size)
    cache_len = args.prompt_len + args.gen

    t0 = time.time()
    if isinstance(model, EncDec):
        frames = jax.random.normal(k_frames, (args.batch, cfg.num_mm_tokens,
                                              cfg.d_model))
        prefill = jax.jit(lambda p, f, t: model.prefill(p, f, t, cache_len))
        logits, cache, t = prefill(params, frames, tokens)
    else:
        prefill = jax.jit(lambda p, t: model.prefill(p, t, cache_len))
        logits, cache, t = prefill(params, tokens)
    prefill_s = time.time() - t0
    meters.histogram("serve.prefill_s").observe(prefill_s)
    meters.counter("serve.prefills").inc()
    print(f"prefill: batch={args.batch} len={args.prompt_len} "
          f"({prefill_s:.1f}s incl. compile)")

    decode = jax.jit(model.decode_step)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    out = [tok]
    t0 = time.time()
    for i in range(args.gen - 1):
        step_t0 = time.time()
        logits, cache = decode(params, cache, tok, t + i)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out.append(tok)
        meters.histogram("serve.decode_step_s").observe(time.time() - step_t0)
    jax.block_until_ready(tok)
    dt = time.time() - t0
    meters.counter("serve.tokens").inc(args.gen * args.batch)
    meters.gauge("serve.tokens_per_s").set(args.gen * args.batch / max(dt, 1e-9))
    gen = jnp.stack(out, axis=1)
    print(f"decoded {args.gen} tokens x {args.batch} seqs in {dt:.2f}s "
          f"({args.gen*args.batch/max(dt,1e-9):.1f} tok/s incl. compile)")
    print("sample token ids:", gen[0, :12].tolist())
    assert bool(jnp.all(jnp.isfinite(logits))), "NaN logits"
    print("serve OK")
    if http is not None:
        print("serving metrics until interrupted (ctrl-c to exit)")
        try:
            while True:
                time.sleep(1.0)
        except KeyboardInterrupt:
            pass
        finally:
            http.stop()


if __name__ == "__main__":
    main()
