"""mamba2-370m — attention-free SSM, SSD (state-space duality) [arXiv:2405.21060].

48L, d_model=1024, ssm_state=128, head_dim=64 (=> 32 SSD heads at expand=2),
vocab=50280. Sub-quadratic: runs long_500k natively (O(1) decode state).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    num_layers=48,
    d_model=1024,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=128,
    conv_width=4,
    block_pattern=("ssm",),
    tie_embeddings=True,
    source="arXiv:2405.21060",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        num_layers=2, d_model=128, ssm_state=16, ssm_head_dim=32, ssm_chunk=8,
        vocab_size=512, dtype="float32",
    )
