"""Config dataclasses + registry for repro.

Every assigned architecture gets a module in this package defining
``CONFIG: ModelConfig`` (full size, dry-run only) and ``smoke_config()``
(reduced variant for CPU tests). ``get_config(arch_id)`` resolves dash or
underscore ids.
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // num_heads
    qkv_bias: bool = False
    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    shared_experts: int = 0          # always-on shared expert count (llama4: 1, moonlight: 2)
    moe_aux_coef: float = 0.01
    capacity_factor: float = 1.25
    # --- SSM (mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 64
    conv_width: int = 4
    use_pallas_ssd: bool = False     # route the SSD inner chunk through the
                                     # Pallas kernel (interpret off-TPU)
    # --- hybrid block pattern, repeated to cover num_layers ---
    # entries: "attn" (attention + FFN), "ssm" (mamba2 mixer), "rec" (RG-LRU + FFN)
    block_pattern: Tuple[str, ...] = ("attn",)
    rnn_width: int = 0               # RG-LRU recurrent width (0 -> d_model)
    # --- attention ---
    rope_theta: float = 10000.0
    attn_window: int = 0             # 0 = full causal; >0 = sliding window
    # --- encoder-decoder ---
    enc_layers: int = 0              # >0 -> enc-dec model (num_layers = decoder)
    # --- multimodal frontend stub ---
    modality: str = "text"           # text | vision | audio
    num_mm_tokens: int = 0           # stub patch/frame embeddings prepended
    # --- numerics ---
    param_dtype: str = "float32"
    dtype: str = "bfloat16"          # activation/compute dtype
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    # --- scan/remat ---
    remat: bool = True
    source: str = ""                 # citation

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.num_heads if self.num_heads else 0

    @property
    def pattern_for(self) -> Tuple[str, ...]:
        return self.block_pattern

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# FL / compressor config (the paper's knobs)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class CompressorConfig:
    kind: str = "threesfc"           # threesfc | topk | randk | signsgd | stc | identity | fedsynth
    error_feedback: bool = True      # paper Eq. 6
    # 3SFC knobs
    syn_batch: int = 1               # n data samples in D_syn (paper: 1)
    syn_seq: int = 16                # synthetic sequence length for LM-family
    syn_steps: int = 1               # S in Algorithm 1
    syn_lr: float = 0.1              # eta for the S optimization steps
    l2_coef: float = 0.0             # lambda (paper uses 0)
    soft_label_rank: int = 0         # 0 = full vocab soft labels; >0 low-rank factored
    # top-k / STC knobs
    keep_ratio: float = 0.01
    # fedsynth baseline
    unroll_steps: int = 5
    # wire-format dtype policy for the serialized payload (repro.comm):
    # fp32 (lossless) | fp16 | bf16 — applies to the 3SFC (D_syn) streams
    wire_dtype: str = "fp32"


@dataclass(frozen=True)
class FLConfig:
    num_clients: int = 8
    local_steps: int = 5             # K
    local_lr: float = 0.01
    local_batch: int = 32
    server_lr: float = 1.0           # 1.0 => plain FedAvg averaging
    rounds: int = 20
    dirichlet_alpha: float = 0.5
    aggregation: str = "mean"        # mean | weighted
    compressor: CompressorConfig = field(default_factory=CompressorConfig)
    seed: int = 0


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    mode: str                        # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

ARCH_IDS = [
    "seamless-m4t-medium",
    "mamba2-370m",
    "mistral-nemo-12b",
    "internvl2-1b",
    "tinyllama-1.1b",
    "qwen3-moe-30b-a3b",
    "moonshot-v1-16b-a3b",
    "llama4-scout-17b-a16e",
    "qwen1.5-0.5b",
    "recurrentgemma-2b",
]

PAPER_MODEL_IDS = ["paper-mlp", "paper-mnistnet", "paper-convnet", "paper-resnet", "paper-regnet"]


def _module_name(arch_id: str) -> str:
    return arch_id.replace("-", "_").replace(".", "_")


def get_config(arch_id: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_module_name(arch_id)}")
    return mod.CONFIG


def get_smoke_config(arch_id: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_module_name(arch_id)}")
    return mod.smoke_config()


def list_archs():
    return list(ARCH_IDS)
