"""RunConfig: every knob of one federated run, validated at construction.

Before this module the round-construction knobs were scattered across four
surfaces — ``FLConfig`` (+ its nested ``CompressorConfig``), the ``wire=``
/ ``codec=`` pair, the ``client_parallel=`` / ``mesh=`` pair and the
``fused_decode`` / ``num_micro`` extras — each validated (or not) at a
different layer. ``RunConfig`` is the one frozen object the round pipeline
(``repro.fl.round.build_fl_round``), the training CLI
(``repro.launch.train``), the AOT entry specs (``repro.launch.specs``) and
the benchmark harness consume:

* construction-time validation: illegal ``client_parallel``/``wire``
  values, a shard_map fan-out without a mesh, or a client count that does
  not divide the mesh's client axes all fail at ``RunConfig(...)`` time —
  not at trace time three layers deeper.
* ``to_json()``/``from_json()`` round-trip every serializable field (the
  mesh is runtime state: re-attach it via ``from_json(d, mesh=...)``), so
  a run's exact configuration can be logged next to its metrics.
* ``from_flags(args, compressor=...)`` builds one from the training CLI's
  argparse namespace — the single mapping from flag names to config fields
  (see ROADMAP.md for the old-flag -> field table).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple

from repro.configs.base import CompressorConfig, FLConfig

CLIENT_PARALLEL_MODES = ("vmap", "shard_map")
WIRE_MODES = ("float", "codec")


@dataclass(frozen=True)
class RunConfig:
    """One federated run: FL schedule + compressor + transport knobs."""

    fl: FLConfig = field(default_factory=FLConfig)
    # client fan-out: single-program vmap (the bit-exactness oracle) or an
    # explicitly sharded shard_map over client_axes(mesh)
    client_parallel: str = "vmap"
    # what crosses the client/server boundary: float trees (accounted
    # bytes) or framed uint8 codec buffers (measured bytes)
    wire: str = "float"
    # dtype policy for the serialized synthetic payload (codec wire only)
    wire_policy: str = "fp32"
    # strategy-declared capability: aggregate from the batched payloads
    # (3SFC: one replicated backward) instead of gathered reconstructions
    fused_decode: bool = False
    # gradient microbatching depth inside each local step
    num_micro: int = 1
    # -- fault model (repro.fl.faults) ------------------------------------
    # fraction of clients scheduled each round; 1.0 = everyone (no faults)
    participation_rate: float = 1.0
    # probability a participating client's payload is lost mid-round
    drop_rate: float = 0.0
    # probability a delivered payload is a straggler (arrives 1..staleness_max
    # rounds late); requires staleness_max >= 1
    straggler_rate: float = 0.0
    # staleness bound k: round-t payloads may arrive up to round t+k, held
    # in the FLState ring buffer with weight 1/(1+delay). 0 = buffer off.
    staleness_max: int = 0
    # PRNG seed of the fault stream — schedules are a pure function of
    # (fault_seed, round), independent of eval-block grouping
    fault_seed: int = 0
    # runtime state, never serialized; required for shard_map, optional
    # for vmap (pins the fused path's replication constraint)
    mesh: Optional[Any] = field(default=None, compare=False)

    def __post_init__(self):
        if self.client_parallel not in CLIENT_PARALLEL_MODES:
            raise ValueError(
                f"client_parallel must be 'vmap' or 'shard_map', got "
                f"{self.client_parallel!r}")
        if self.wire not in WIRE_MODES:
            raise ValueError(
                f"wire must be 'float' or 'codec', got {self.wire!r}")
        if self.num_micro < 1:
            raise ValueError(f"num_micro must be >= 1, got {self.num_micro}")
        if not 0.0 < self.participation_rate <= 1.0:
            raise ValueError(
                f"participation_rate must be in (0, 1], got "
                f"{self.participation_rate}")
        if not 0.0 <= self.drop_rate < 1.0:
            raise ValueError(
                f"drop_rate must be in [0, 1), got {self.drop_rate}")
        if not 0.0 <= self.straggler_rate <= 1.0:
            raise ValueError(
                f"straggler_rate must be in [0, 1], got {self.straggler_rate}")
        if self.staleness_max < 0:
            raise ValueError(
                f"staleness_max must be >= 0, got {self.staleness_max}")
        if self.straggler_rate > 0.0 and self.staleness_max < 1:
            raise ValueError(
                "straggler_rate > 0 requires staleness_max >= 1 (a straggler "
                "needs a buffer slot to land in)")
        if self.fused_decode and self.staleness_max > 0:
            raise ValueError(
                "fused_decode is incompatible with staleness_max > 0: the "
                "staleness buffer banks per-client reconstructions, which "
                "the fused aggregate never materializes — use the default "
                "decode path for stale rounds")
        if self.client_parallel == "shard_map":
            if self.mesh is None:
                raise ValueError(
                    "client_parallel='shard_map' requires an explicit mesh "
                    "(see repro.fl.sharding.make_fl_shardings)")
            # the shard-count/divisibility policy is FLShardings' — one
            # source of truth for the mesh contract (imported lazily:
            # fl.sharding sits above this package)
            from repro.fl.sharding import make_fl_shardings
            make_fl_shardings(self.mesh).check_divisible(self.fl.num_clients)

    # -- derived -----------------------------------------------------------
    @property
    def has_faults(self) -> bool:
        """True when any fault knob is non-default. The round builder keys
        the masked pipeline on this — a zero-fault config compiles the
        EXACT unfaulted round (the bitwise gate's trivial half; the masked
        pipeline under a null schedule is the gated, non-trivial half)."""
        return (self.participation_rate < 1.0 or self.drop_rate > 0.0
                or self.straggler_rate > 0.0 or self.staleness_max > 0)

    def client_axes(self) -> Optional[Tuple[str, ...]]:
        """Mesh axes of the shard_map fan-out; None for the vmap fan-out."""
        if self.client_parallel != "shard_map":
            return None
        from repro.fl.sharding import make_fl_shardings
        return make_fl_shardings(self.mesh).axes

    def replace(self, **kw) -> "RunConfig":
        return dataclasses.replace(self, **kw)

    # -- serialization -----------------------------------------------------
    def to_json(self) -> Dict[str, Any]:
        """JSON-serializable dict of every field except the runtime mesh."""
        return {
            "fl": dataclasses.asdict(self.fl),
            "client_parallel": self.client_parallel,
            "wire": self.wire,
            "wire_policy": self.wire_policy,
            "fused_decode": self.fused_decode,
            "num_micro": self.num_micro,
            "participation_rate": self.participation_rate,
            "drop_rate": self.drop_rate,
            "straggler_rate": self.straggler_rate,
            "staleness_max": self.staleness_max,
            "fault_seed": self.fault_seed,
        }

    @classmethod
    def from_json(cls, d: Dict[str, Any], *, mesh=None) -> "RunConfig":
        fl_d = dict(d["fl"])
        comp = CompressorConfig(**fl_d.pop("compressor"))
        return cls(fl=FLConfig(compressor=comp, **fl_d),
                   client_parallel=d.get("client_parallel", "vmap"),
                   wire=d.get("wire", "float"),
                   wire_policy=d.get("wire_policy", "fp32"),
                   fused_decode=d.get("fused_decode", False),
                   num_micro=d.get("num_micro", 1),
                   participation_rate=d.get("participation_rate", 1.0),
                   drop_rate=d.get("drop_rate", 0.0),
                   straggler_rate=d.get("straggler_rate", 0.0),
                   staleness_max=d.get("staleness_max", 0),
                   fault_seed=d.get("fault_seed", 0),
                   mesh=mesh)

    @classmethod
    def from_flags(cls, args, *, compressor: CompressorConfig,
                   client_parallel: str = "vmap", mesh=None) -> "RunConfig":
        """Build from the training CLI's argparse namespace.

        ``compressor`` is resolved by the driver (budget tables need the
        model); ``client_parallel`` arrives already de-'auto'-ed (the
        device-count probe is the driver's job, not a config's).
        """
        fl = FLConfig(
            num_clients=args.clients,
            local_steps=args.local_steps,
            local_lr=args.lr,
            local_batch=args.batch,
            rounds=args.rounds,
            dirichlet_alpha=getattr(args, "alpha", 0.5),
            compressor=compressor,
            seed=args.seed,
        )
        return cls(fl=fl,
                   client_parallel=client_parallel,
                   wire=getattr(args, "wire", "float"),
                   wire_policy=getattr(args, "wire_policy", "fp32"),
                   participation_rate=getattr(args, "participation_rate", 1.0),
                   drop_rate=getattr(args, "drop_rate", 0.0),
                   straggler_rate=getattr(args, "straggler_rate", 0.0),
                   staleness_max=getattr(args, "staleness_max", 0),
                   fault_seed=getattr(args, "fault_seed", 0),
                   mesh=mesh)
