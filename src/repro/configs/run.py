"""RunConfig: every knob of one federated run, validated at construction.

Before this module the round-construction knobs were scattered across four
surfaces — ``FLConfig`` (+ its nested ``CompressorConfig``), the ``wire=``
/ ``codec=`` pair, the ``client_parallel=`` / ``mesh=`` pair and the
``fused_decode`` / ``num_micro`` extras — each validated (or not) at a
different layer. ``RunConfig`` is the one frozen object the round pipeline
(``repro.fl.round.build_fl_round``), the training CLI
(``repro.launch.train``), the AOT entry specs (``repro.launch.specs``) and
the benchmark harness consume:

* construction-time validation: illegal ``client_parallel``/``wire``
  values, a shard_map fan-out without a mesh, or a client count that does
  not divide the mesh's client axes all fail at ``RunConfig(...)`` time —
  not at trace time three layers deeper.
* ``to_json()``/``from_json()`` round-trip every serializable field (the
  mesh is runtime state: re-attach it via ``from_json(d, mesh=...)``), so
  a run's exact configuration can be logged next to its metrics.
* ``from_flags(args, compressor=...)`` builds one from the training CLI's
  argparse namespace — the single mapping from flag names to config fields
  (see ROADMAP.md for the old-flag -> field table).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple

from repro.configs.base import CompressorConfig, FLConfig

CLIENT_PARALLEL_MODES = ("vmap", "shard_map")
WIRE_MODES = ("float", "codec")
TRANSPORT_MODES = ("inproc", "socket")


@dataclass(frozen=True)
class RunConfig:
    """One federated run: FL schedule + compressor + transport knobs."""

    fl: FLConfig = field(default_factory=FLConfig)
    # client fan-out: single-program vmap (the bit-exactness oracle) or an
    # explicitly sharded shard_map over client_axes(mesh)
    client_parallel: str = "vmap"
    # what crosses the client/server boundary: float trees (accounted
    # bytes) or framed uint8 codec buffers (measured bytes)
    wire: str = "float"
    # dtype policy for the serialized synthetic payload (codec wire only)
    wire_policy: str = "fp32"
    # strategy-declared capability: aggregate from the batched payloads
    # (3SFC: one replicated backward) instead of gathered reconstructions
    fused_decode: bool = False
    # gradient microbatching depth inside each local step
    num_micro: int = 1
    # -- fault model (repro.fl.faults) ------------------------------------
    # fraction of clients scheduled each round; 1.0 = everyone (no faults)
    participation_rate: float = 1.0
    # probability a participating client's payload is lost mid-round
    drop_rate: float = 0.0
    # probability a delivered payload is a straggler (arrives 1..staleness_max
    # rounds late); requires staleness_max >= 1
    straggler_rate: float = 0.0
    # staleness bound k: round-t payloads may arrive up to round t+k, held
    # in the FLState ring buffer with weight 1/(1+delay). 0 = buffer off.
    staleness_max: int = 0
    # PRNG seed of the fault stream — schedules are a pure function of
    # (fault_seed, round), independent of eval-block grouping
    fault_seed: int = 0
    # -- transport (repro.comm.transport) ----------------------------------
    # how rounds move: 'inproc' (one process, the engine's scanned loop) or
    # 'socket' (a SocketServer + N worker processes over the live loop)
    transport: str = "inproc"
    # hard bound on one round's collect phase: a straggler delays the
    # round by at most this, never by its full delay
    round_deadline_s: float = 30.0
    # per-client receive window before the first RESEND ...
    recv_timeout_s: float = 2.0
    # ... growing by this factor per attempt (exponential backoff)
    recv_backoff: float = 2.0
    # RESENDs before a client is given up as dropped this round
    transport_retries: int = 2
    # worker liveness tick period (heartbeats flow even mid-compute) ...
    heartbeat_s: float = 0.5
    # ... and how long silence lasts before a worker counts as dead
    liveness_timeout_s: float = 5.0
    # -- recovery (repro.checkpoint) ---------------------------------------
    # full-state checkpoint cadence in rounds (0 = final-only): every
    # ckpt_every-th round boundary writes a durable recovery point the run
    # can be resumed from bitwise (params + EF + staleness buffer + round
    # counter + byte ledger; see repro.checkpoint.save_fl_checkpoint)
    ckpt_every: int = 0
    # runtime state, never serialized; required for shard_map, optional
    # for vmap (pins the fused path's replication constraint)
    mesh: Optional[Any] = field(default=None, compare=False)

    def __post_init__(self):
        if self.client_parallel not in CLIENT_PARALLEL_MODES:
            raise ValueError(
                f"client_parallel must be 'vmap' or 'shard_map', got "
                f"{self.client_parallel!r}")
        if self.wire not in WIRE_MODES:
            raise ValueError(
                f"wire must be 'float' or 'codec', got {self.wire!r}")
        if self.num_micro < 1:
            raise ValueError(f"num_micro must be >= 1, got {self.num_micro}")
        if not 0.0 < self.participation_rate <= 1.0:
            raise ValueError(
                f"participation_rate must be in (0, 1], got "
                f"{self.participation_rate}")
        if not 0.0 <= self.drop_rate < 1.0:
            raise ValueError(
                f"drop_rate must be in [0, 1), got {self.drop_rate}")
        if not 0.0 <= self.straggler_rate <= 1.0:
            raise ValueError(
                f"straggler_rate must be in [0, 1], got {self.straggler_rate}")
        if self.staleness_max < 0:
            raise ValueError(
                f"staleness_max must be >= 0, got {self.staleness_max}")
        if self.straggler_rate > 0.0 and self.staleness_max < 1:
            raise ValueError(
                "straggler_rate > 0 requires staleness_max >= 1 (a straggler "
                "needs a buffer slot to land in)")
        if self.transport not in TRANSPORT_MODES:
            raise ValueError(
                f"transport must be 'inproc' or 'socket', got "
                f"{self.transport!r}")
        if self.transport == "socket":
            if self.wire != "codec":
                raise ValueError(
                    "transport='socket' requires wire='codec': only framed "
                    "uint8 buffers cross a real wire")
            if self.client_parallel != "vmap":
                raise ValueError(
                    "transport='socket' requires client_parallel='vmap': "
                    "worker processes ARE the client fan-out (shard_map is "
                    "the in-process mesh path)")
            if self.has_faults:
                raise ValueError(
                    "transport='socket' is incompatible with the schedule-"
                    "driven fault knobs: on a live wire, faults are real "
                    "transport events (timeouts, corruption, dead workers) "
                    "mapped onto delivered=False — inject them at the "
                    "transport (SocketServer rx_filter) instead")
        if self.round_deadline_s <= 0.0:
            raise ValueError(
                f"round_deadline_s must be > 0, got {self.round_deadline_s}")
        if self.recv_timeout_s <= 0.0:
            raise ValueError(
                f"recv_timeout_s must be > 0, got {self.recv_timeout_s}")
        if self.recv_backoff < 1.0:
            raise ValueError(
                f"recv_backoff must be >= 1.0, got {self.recv_backoff}")
        if self.transport_retries < 0:
            raise ValueError(
                f"transport_retries must be >= 0, got "
                f"{self.transport_retries}")
        if self.heartbeat_s <= 0.0:
            raise ValueError(
                f"heartbeat_s must be > 0, got {self.heartbeat_s}")
        if self.liveness_timeout_s <= self.heartbeat_s:
            raise ValueError(
                f"liveness_timeout_s ({self.liveness_timeout_s}) must "
                f"exceed heartbeat_s ({self.heartbeat_s}) — a window "
                f"shorter than one heartbeat declares every worker dead")
        if self.ckpt_every < 0:
            raise ValueError(
                f"ckpt_every must be >= 0 (0 = final checkpoint only), got "
                f"{self.ckpt_every}")
        if self.fused_decode and self.staleness_max > 0:
            raise ValueError(
                "fused_decode is incompatible with staleness_max > 0: the "
                "staleness buffer banks per-client reconstructions, which "
                "the fused aggregate never materializes — use the default "
                "decode path for stale rounds")
        if self.client_parallel == "shard_map":
            if self.mesh is None:
                raise ValueError(
                    "client_parallel='shard_map' requires an explicit mesh "
                    "(see repro.fl.sharding.make_fl_shardings)")
            # the shard-count/divisibility policy is FLShardings' — one
            # source of truth for the mesh contract (imported lazily:
            # fl.sharding sits above this package)
            from repro.fl.sharding import make_fl_shardings
            make_fl_shardings(self.mesh).check_divisible(self.fl.num_clients)

    # -- derived -----------------------------------------------------------
    @property
    def has_faults(self) -> bool:
        """True when any fault knob is non-default. The round builder keys
        the masked pipeline on this — a zero-fault config compiles the
        EXACT unfaulted round (the bitwise gate's trivial half; the masked
        pipeline under a null schedule is the gated, non-trivial half)."""
        return (self.participation_rate < 1.0 or self.drop_rate > 0.0
                or self.straggler_rate > 0.0 or self.staleness_max > 0)

    def client_axes(self) -> Optional[Tuple[str, ...]]:
        """Mesh axes of the shard_map fan-out; None for the vmap fan-out."""
        if self.client_parallel != "shard_map":
            return None
        from repro.fl.sharding import make_fl_shardings
        return make_fl_shardings(self.mesh).axes

    def retry_policy(self):
        """The transport ``RetryPolicy`` these knobs describe: retry count
        + backoff schedule, with single-receive windows capped by the
        round deadline (no receive may outwait the round)."""
        from repro.fl.engine import RetryPolicy
        return RetryPolicy(
            max_retries=self.transport_retries,
            recv_timeout_s=self.recv_timeout_s,
            recv_backoff=self.recv_backoff,
            max_timeout_s=max(self.round_deadline_s, self.recv_timeout_s))

    def replace(self, **kw) -> "RunConfig":
        return dataclasses.replace(self, **kw)

    # -- serialization -----------------------------------------------------
    def to_json(self) -> Dict[str, Any]:
        """JSON-serializable dict of every field except the runtime mesh."""
        return {
            "fl": dataclasses.asdict(self.fl),
            "client_parallel": self.client_parallel,
            "wire": self.wire,
            "wire_policy": self.wire_policy,
            "fused_decode": self.fused_decode,
            "num_micro": self.num_micro,
            "participation_rate": self.participation_rate,
            "drop_rate": self.drop_rate,
            "straggler_rate": self.straggler_rate,
            "staleness_max": self.staleness_max,
            "fault_seed": self.fault_seed,
            "transport": self.transport,
            "round_deadline_s": self.round_deadline_s,
            "recv_timeout_s": self.recv_timeout_s,
            "recv_backoff": self.recv_backoff,
            "transport_retries": self.transport_retries,
            "heartbeat_s": self.heartbeat_s,
            "liveness_timeout_s": self.liveness_timeout_s,
            "ckpt_every": self.ckpt_every,
        }

    @classmethod
    def from_json(cls, d: Dict[str, Any], *, mesh=None) -> "RunConfig":
        fl_d = dict(d["fl"])
        comp = CompressorConfig(**fl_d.pop("compressor"))
        return cls(fl=FLConfig(compressor=comp, **fl_d),
                   client_parallel=d.get("client_parallel", "vmap"),
                   wire=d.get("wire", "float"),
                   wire_policy=d.get("wire_policy", "fp32"),
                   fused_decode=d.get("fused_decode", False),
                   num_micro=d.get("num_micro", 1),
                   participation_rate=d.get("participation_rate", 1.0),
                   drop_rate=d.get("drop_rate", 0.0),
                   straggler_rate=d.get("straggler_rate", 0.0),
                   staleness_max=d.get("staleness_max", 0),
                   fault_seed=d.get("fault_seed", 0),
                   transport=d.get("transport", "inproc"),
                   round_deadline_s=d.get("round_deadline_s", 30.0),
                   recv_timeout_s=d.get("recv_timeout_s", 2.0),
                   recv_backoff=d.get("recv_backoff", 2.0),
                   transport_retries=d.get("transport_retries", 2),
                   heartbeat_s=d.get("heartbeat_s", 0.5),
                   liveness_timeout_s=d.get("liveness_timeout_s", 5.0),
                   ckpt_every=d.get("ckpt_every", 0),
                   mesh=mesh)

    @classmethod
    def from_flags(cls, args, *, compressor: CompressorConfig,
                   client_parallel: str = "vmap", mesh=None) -> "RunConfig":
        """Build from the training CLI's argparse namespace.

        ``compressor`` is resolved by the driver (budget tables need the
        model); ``client_parallel`` arrives already de-'auto'-ed (the
        device-count probe is the driver's job, not a config's).
        """
        fl = FLConfig(
            num_clients=args.clients,
            local_steps=args.local_steps,
            local_lr=args.lr,
            local_batch=args.batch,
            rounds=args.rounds,
            dirichlet_alpha=getattr(args, "alpha", 0.5),
            compressor=compressor,
            seed=args.seed,
        )
        return cls(fl=fl,
                   client_parallel=client_parallel,
                   wire=getattr(args, "wire", "float"),
                   wire_policy=getattr(args, "wire_policy", "fp32"),
                   participation_rate=getattr(args, "participation_rate", 1.0),
                   drop_rate=getattr(args, "drop_rate", 0.0),
                   straggler_rate=getattr(args, "straggler_rate", 0.0),
                   staleness_max=getattr(args, "staleness_max", 0),
                   fault_seed=getattr(args, "fault_seed", 0),
                   transport=getattr(args, "transport", "inproc"),
                   round_deadline_s=getattr(args, "round_deadline_s", 30.0),
                   recv_timeout_s=getattr(args, "recv_timeout_s", 2.0),
                   recv_backoff=getattr(args, "recv_backoff", 2.0),
                   transport_retries=getattr(args, "transport_retries", 2),
                   heartbeat_s=getattr(args, "heartbeat_s", 0.5),
                   liveness_timeout_s=getattr(args, "liveness_timeout_s", 5.0),
                   ckpt_every=getattr(args, "ckpt_every", 0),
                   mesh=mesh)
