"""qwen1.5-0.5b — dense with QKV bias [hf:Qwen/Qwen1.5-0.5B].

24L, d_model=1024, 16H (kv=16 = MHA), d_ff=2816, vocab=151936, QKV bias,
tied embeddings.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-0.5b",
    family="dense",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=2816,
    vocab_size=151936,
    qkv_bias=True,
    tie_embeddings=True,
    source="hf:Qwen/Qwen1.5-0.5B",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=4, d_ff=256,
        vocab_size=512, dtype="float32",
    )
