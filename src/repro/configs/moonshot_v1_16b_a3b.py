"""moonshot-v1-16b-a3b — Moonlight-style MoE [hf:moonshotai/Moonlight-16B-A3B].

48L, d_model=2048, 16H (kv=16 = MHA), per-expert d_ff=1408, 64 experts top-6
plus 2 shared experts (DeepSeek-V3-style), vocab=163840.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,
    vocab_size=163840,
    num_experts=64,
    experts_per_token=6,
    shared_experts=2,
    source="hf:moonshotai/Moonlight-16B-A3B",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=4, d_ff=64,
        vocab_size=512, num_experts=4, experts_per_token=2, shared_experts=1,
        dtype="float32",
    )
