from repro.configs.base import (
    ARCH_IDS,
    INPUT_SHAPES,
    CompressorConfig,
    FLConfig,
    ModelConfig,
    ShapeConfig,
    get_config,
    get_smoke_config,
    list_archs,
)
from repro.configs.run import RunConfig
