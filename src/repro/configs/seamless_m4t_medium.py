"""seamless-m4t-medium — enc-dec multimodal (audio) [arXiv:2308.11596].

12L encoder + 12L decoder, d_model=1024, 16H (GQA kv=16 = MHA), d_ff=4096,
vocab=256206. The speech frontend (mel + conv feature extractor) is a stub:
``input_specs`` supplies precomputed frame embeddings (B, T_frames, 1024).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="audio",
    num_layers=12,             # decoder
    enc_layers=12,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4096,
    vocab_size=256206,
    modality="audio",
    num_mm_tokens=512,         # stub audio frames per example (train/prefill)
    source="arXiv:2308.11596",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        num_layers=2, enc_layers=2, d_model=128, num_heads=4, num_kv_heads=4,
        d_ff=256, vocab_size=512, num_mm_tokens=8, dtype="float32",
    )
