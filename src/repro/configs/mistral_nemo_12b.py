"""mistral-nemo-12b — dense GQA, 128k ctx [hf:mistralai/Mistral-Nemo-Base-2407].

40L, d_model=5120, 32H (GQA kv=8), head_dim=128, d_ff=14336, vocab=131072.
Full attention at base; the long_500k serving variant uses the mistral-family
sliding window (8192) as a first-class ``attn_window`` flag.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mistral-nemo-12b",
    family="dense",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=131072,
    rope_theta=1e6,
    source="hf:mistralai/Mistral-Nemo-Base-2407",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        num_layers=2, d_model=256, num_heads=8, num_kv_heads=2, head_dim=32,
        d_ff=512, vocab_size=512, dtype="float32",
    )
