"""tinyllama-1.1b — dense llama2-arch small [arXiv:2401.02385].

22L, d_model=2048, 32H (GQA kv=4), head_dim=64, d_ff=5632, vocab=32000.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="tinyllama-1.1b",
    family="dense",
    num_layers=22,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    d_ff=5632,
    vocab_size=32000,
    source="arXiv:2401.02385",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        num_layers=2, d_model=256, num_heads=8, num_kv_heads=2, d_ff=512,
        vocab_size=512, dtype="float32",
    )
