"""recurrentgemma-2b — Griffin hybrid: RG-LRU + local attention 1:2
[arXiv:2402.19427].

26L (pattern rec,rec,attn -> 8 periods + 2-block tail), d_model=2560,
10H (MQA kv=1), head_dim=256, d_ff=7680, vocab=256000, local attention
window 2048, recurrent width 2560. Sub-quadratic: native long_500k.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    block_pattern=("rec", "rec", "attn"),
    rnn_width=2560,
    attn_window=2048,
    conv_width=4,
    tie_embeddings=True,
    source="arXiv:2402.19427",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        num_layers=5, d_model=128, num_heads=4, num_kv_heads=1, head_dim=32,
        d_ff=256, vocab_size=512, rnn_width=128, attn_window=16,
        dtype="float32",
    )
