"""llama4-scout-17b-a16e — MoE 16 experts top-1, early fusion
[hf:meta-llama/Llama-4-Scout-17B-16E].

48L, d_model=5120, 40H (GQA kv=8), per-expert d_ff=8192, 16 experts top-1
plus 1 shared expert, vocab=202048. Llama4's iRoPE chunked-local attention
(8192) is the native sub-quadratic mode used for long_500k.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    num_experts=16,
    experts_per_token=1,
    shared_experts=1,
    rope_theta=5e5,
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=2, head_dim=32,
        d_ff=128, vocab_size=512, num_experts=4, experts_per_token=1,
        shared_experts=1, dtype="float32",
    )
