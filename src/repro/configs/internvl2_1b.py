"""internvl2-1b — VLM: InternViT + Qwen2-0.5B LM backbone [arXiv:2404.16821].

Backbone: 24L, d_model=896, 14H (GQA kv=2), d_ff=4864, vocab=151655, QKV bias
(Qwen2 family). The vision encoder + MLP projector are a stub: ``input_specs``
supplies projected patch embeddings (B, 256, 896).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b",
    family="vlm",
    num_layers=24,
    d_model=896,
    num_heads=14,
    num_kv_heads=2,
    d_ff=4864,
    vocab_size=151655,
    qkv_bias=True,
    tie_embeddings=True,
    rope_theta=1e6,
    modality="vision",
    num_mm_tokens=256,
    source="arXiv:2404.16821",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        num_layers=2, d_model=112, num_heads=7, num_kv_heads=1, d_ff=256,
        vocab_size=512, num_mm_tokens=4, dtype="float32",
    )
