from repro.checkpoint.ckpt import (MANIFEST_VERSION, CheckpointError,
                                   CheckpointKeyError, CheckpointManager,
                                   CheckpointMissingError,
                                   CheckpointShapeError,
                                   CheckpointVersionError, load_arrays,
                                   load_checkpoint, load_fl_checkpoint,
                                   load_manifest, save_checkpoint,
                                   save_fl_checkpoint)

__all__ = [
    "MANIFEST_VERSION",
    "CheckpointError",
    "CheckpointKeyError",
    "CheckpointManager",
    "CheckpointMissingError",
    "CheckpointShapeError",
    "CheckpointVersionError",
    "load_arrays",
    "load_checkpoint",
    "load_fl_checkpoint",
    "load_manifest",
    "save_checkpoint",
    "save_fl_checkpoint",
]
