"""Durable pytree checkpointing: atomic files, typed errors, a versioned
step index, and full-FLState helpers.

A checkpoint is a directory of two files — ``arrays.npz`` (flat payload,
keys are the '/'-joined leaf paths) and ``manifest.json`` (shapes, dtypes,
format version, free-form meta). Both are written atomically
(tmp + fsync + rename + directory fsync) with the manifest LAST, so the
manifest's existence is the commit record: a crash mid-write leaves either
a complete checkpoint or a directory ``load_checkpoint`` rejects with a
typed error, never a silently-corrupt one.

``CheckpointManager`` layers a retention-managed step index on top::

    root/
      MANIFEST.json          # {"version", "steps": [...], "latest": s}
      step_00000004/         # one save_checkpoint dir per step
      step_00000008/

The root ``MANIFEST.json`` is itself renamed into place, so *it* is the
commit point for a step: a step directory that crashed mid-write is never
listed, and ``latest()`` always names a loadable checkpoint (the
crash-during-checkpoint-write gate of ``benchmarks/bench_recovery``).

``save_fl_checkpoint``/``load_fl_checkpoint`` fix the schema for a full
recovery point of a federated run: the complete ``FLState`` (params, the
N×d EF tree, the staleness ring buffer, the round counter), the
``RunConfig`` JSON (which carries the PRNG and fault seeds), the
``LinkStats`` byte ledger, the live loop's round history, and — for the
socket transport — the server's per-client EF bank, which is what a
rejoining worker is re-synced from.

Error taxonomy: everything raises ``CheckpointError`` subclasses —
``CheckpointMissingError`` (no such checkpoint / file), ``CheckpointKeyError``
(a leaf the target structure wants is absent), ``CheckpointShapeError``
(shape or dtype mismatch between payload, manifest, and target), and
``CheckpointVersionError`` (a manifest written by a future format version).
"""
from __future__ import annotations

import io
import json
import os
import shutil
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import get_tracer

PyTree = Any

MANIFEST_VERSION = 1

# dtypes stored as-is; anything else (bf16, fp8, ...) is widened to f32 on
# save (exact for bf16) and cast back to the target leaf's dtype on load
_STORED_DTYPES = (np.float32, np.float64, np.int32, np.int64, np.uint32,
                  np.uint8, np.int8, np.bool_, np.float16, np.uint16,
                  np.int16, np.uint64)


class CheckpointError(Exception):
    """Base of every checkpoint failure mode."""


class CheckpointMissingError(CheckpointError, FileNotFoundError):
    """No checkpoint where one was expected (missing dir/manifest/payload)."""


class CheckpointKeyError(CheckpointError, KeyError):
    """The payload lacks a leaf the target structure requires."""


class CheckpointShapeError(CheckpointError, ValueError):
    """Shape or dtype mismatch between payload, manifest, and target."""


class CheckpointVersionError(CheckpointError, ValueError):
    """Manifest written by a future format version — refuse to guess."""


# ---------------------------------------------------------------------------
# flat payload <-> pytree
# ---------------------------------------------------------------------------


def _path_part(p) -> str:
    tu = jax.tree_util
    if isinstance(p, tu.DictKey):
        return str(p.key)
    if isinstance(p, tu.GetAttrKey):
        return str(p.name)
    return str(getattr(p, "idx", getattr(p, "key", p)))


def _leaf_key(path) -> str:
    return "/".join(_path_part(p) for p in path) or "_root"


def _storage_dtype(dtype) -> np.dtype:
    try:
        d = np.dtype(dtype)
    except TypeError:
        return np.dtype(np.float32)
    return d if d.type in _STORED_DTYPES else np.dtype(np.float32)


def _flatten_with_paths(tree: PyTree) -> Dict[str, np.ndarray]:
    out: Dict[str, np.ndarray] = {}

    def visit(path, leaf):
        key = _leaf_key(path)
        arr = np.asarray(leaf)
        out[key] = arr.astype(_storage_dtype(arr.dtype))

    jax.tree_util.tree_map_with_path(visit, tree)
    return out


# ---------------------------------------------------------------------------
# atomic file primitives
# ---------------------------------------------------------------------------


def _fsync_dir(dirname: str) -> None:
    fd = os.open(dirname or ".", os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _atomic_write(path: str, data: bytes) -> None:
    """tmp + fsync + rename + directory fsync: after this returns, ``path``
    holds either its previous content or ``data`` in full — never a prefix."""
    tracer = get_tracer()
    with tracer.span("ckpt.write", file=os.path.basename(path),
                     bytes=len(data)):
        tmp = path + ".tmp"
        with open(tmp, "wb") as f:
            f.write(data)
            f.flush()
            with tracer.span("ckpt.fsync", file=os.path.basename(path)):
                os.fsync(f.fileno())
        os.replace(tmp, path)
        _fsync_dir(os.path.dirname(path))


# ---------------------------------------------------------------------------
# single-checkpoint save / load
# ---------------------------------------------------------------------------


def save_checkpoint(path: str, tree: PyTree, meta: Optional[Dict] = None) -> str:
    """Write one checkpoint directory atomically; returns ``path``.

    File order is the durability contract: the payload lands first, the
    manifest (the commit record) last — a crash between the two leaves a
    directory ``load_checkpoint`` rejects with ``CheckpointMissingError``.
    """
    with get_tracer().span("ckpt.save", path=os.path.basename(path)):
        os.makedirs(path, exist_ok=True)
        flat = _flatten_with_paths(tree)
        buf = io.BytesIO()
        np.savez(buf, **flat)
        _atomic_write(os.path.join(path, "arrays.npz"), buf.getvalue())
        manifest = {
            "version": MANIFEST_VERSION,
            "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                       for k, v in flat.items()},
            "meta": meta or {},
        }
        _atomic_write(os.path.join(path, "manifest.json"),
                      json.dumps(manifest, indent=2).encode("utf-8"))
    return path


def load_manifest(path: str) -> Dict:
    """Read + validate a checkpoint's manifest (the commit record)."""
    mpath = os.path.join(path, "manifest.json")
    try:
        with open(mpath) as f:
            manifest = json.load(f)
    except FileNotFoundError:
        raise CheckpointMissingError(
            f"no checkpoint at {path!r}: missing manifest.json (either never "
            f"written or a save crashed before its commit record)") from None
    except json.JSONDecodeError as e:
        raise CheckpointMissingError(
            f"checkpoint manifest {mpath!r} is not valid JSON: {e}") from None
    version = manifest.get("version", 0)
    if version > MANIFEST_VERSION:
        raise CheckpointVersionError(
            f"checkpoint at {path!r} has manifest version {version}, this "
            f"build reads <= {MANIFEST_VERSION} — refusing to guess at a "
            f"future format")
    return manifest


def load_arrays(path: str) -> Tuple[Dict[str, np.ndarray], Dict]:
    """Load a checkpoint's raw flat payload -> (``{leaf key: array}``,
    manifest). Every array is validated against the manifest's recorded
    shape/dtype; no target structure is required (structure-free loads are
    how drivers read auxiliary trees like the EF bank whose key set is not
    known statically)."""
    manifest = load_manifest(path)
    apath = os.path.join(path, "arrays.npz")
    try:
        with get_tracer().span("ckpt.load", path=os.path.basename(path)), \
                np.load(apath) as data:
            flat = {k: data[k] for k in data.files}
    except FileNotFoundError:
        raise CheckpointMissingError(
            f"checkpoint at {path!r} has a manifest but no arrays.npz") from None
    for key, want in manifest["leaves"].items():
        if key not in flat:
            raise CheckpointKeyError(
                f"checkpoint payload at {path!r} is missing leaf {key!r} "
                f"that its manifest records")
        arr = flat[key]
        if list(arr.shape) != list(want["shape"]) or str(arr.dtype) != want["dtype"]:
            raise CheckpointShapeError(
                f"leaf {key!r} at {path!r}: payload {arr.dtype}{list(arr.shape)} "
                f"!= manifest {want['dtype']}{want['shape']}")
    return flat, manifest


def load_checkpoint(path: str, like: PyTree) -> PyTree:
    """Load into the structure of ``like``, with typed validation: a leaf
    of ``like`` absent from the payload is ``CheckpointKeyError``; a shape
    or stored-dtype mismatch is ``CheckpointShapeError``. Leaves come back
    as jnp arrays in ``like``'s dtype (bf16 etc. round-trip through their
    exact f32 storage)."""
    flat, _ = load_arrays(path)

    def visit(p, leaf):
        key = _leaf_key(p)
        if key not in flat:
            raise CheckpointKeyError(
                f"checkpoint at {path!r} has no leaf {key!r} (target "
                f"structure wants it; payload has {len(flat)} leaves)")
        arr = flat[key]
        want_shape = tuple(jnp.shape(leaf))
        if tuple(arr.shape) != want_shape:
            raise CheckpointShapeError(
                f"leaf {key!r}: checkpoint shape {tuple(arr.shape)} != "
                f"target shape {want_shape}")
        want_store = _storage_dtype(getattr(leaf, "dtype", None)
                                    or np.asarray(leaf).dtype)
        if arr.dtype != want_store:
            raise CheckpointShapeError(
                f"leaf {key!r}: checkpoint stored dtype {arr.dtype} != "
                f"{want_store} expected for target dtype "
                f"{jnp.result_type(leaf)}")
        return jnp.asarray(arr, dtype=jnp.result_type(leaf))

    return jax.tree_util.tree_map_with_path(visit, like)


# ---------------------------------------------------------------------------
# versioned step index
# ---------------------------------------------------------------------------


class CheckpointManager:
    """Retention-managed step index over ``save_checkpoint`` directories.

    The root ``MANIFEST.json`` (atomically renamed into place) is the
    commit point: ``save`` writes the step directory first and registers it
    last, so a crash at ANY point leaves ``latest()`` naming the previous,
    fully-written checkpoint. ``keep`` bounds retained steps (oldest pruned
    after a successful commit; ``keep=0`` retains everything).
    """

    def __init__(self, root: str, *, keep: int = 3):
        if keep < 0:
            raise ValueError(f"keep must be >= 0 (0 = keep all), got {keep}")
        self.root = root
        self.keep = keep

    # -- index -------------------------------------------------------------
    def _index_path(self) -> str:
        return os.path.join(self.root, "MANIFEST.json")

    def _read_index(self) -> Dict:
        try:
            with open(self._index_path()) as f:
                idx = json.load(f)
        except FileNotFoundError:
            return {"version": MANIFEST_VERSION, "steps": [], "latest": None}
        except json.JSONDecodeError as e:
            raise CheckpointMissingError(
                f"checkpoint index {self._index_path()!r} is not valid "
                f"JSON: {e}") from None
        version = idx.get("version", 0)
        if version > MANIFEST_VERSION:
            raise CheckpointVersionError(
                f"checkpoint index at {self.root!r} has version {version}, "
                f"this build reads <= {MANIFEST_VERSION}")
        return idx

    def steps(self) -> List[int]:
        return sorted(int(s) for s in self._read_index()["steps"])

    def latest(self) -> Optional[int]:
        latest = self._read_index()["latest"]
        return None if latest is None else int(latest)

    def path(self, step: int) -> str:
        return os.path.join(self.root, f"step_{int(step):08d}")

    # -- save / load -------------------------------------------------------
    def save(self, step: int, tree: PyTree, meta: Optional[Dict] = None) -> str:
        os.makedirs(self.root, exist_ok=True)
        idx = self._read_index()
        known = {int(s) for s in idx["steps"]}
        p = self.path(step)
        if os.path.isdir(p) and int(step) not in known:
            shutil.rmtree(p)        # debris of a save that crashed mid-write
        save_checkpoint(p, tree, meta)
        steps = sorted(known | {int(step)})
        drop = steps[:-self.keep] if self.keep and len(steps) > self.keep else []
        steps = [s for s in steps if s not in drop]
        _atomic_write(self._index_path(), json.dumps(
            {"version": MANIFEST_VERSION, "steps": steps,
             "latest": max(steps)}).encode("utf-8"))
        for s in drop:              # prune only after the commit point
            shutil.rmtree(self.path(s), ignore_errors=True)
        return p

    def _resolve(self, step: Optional[int]) -> int:
        idx = self._read_index()
        if step is None:
            if idx["latest"] is None:
                raise CheckpointMissingError(
                    f"no checkpoints committed under {self.root!r}")
            return int(idx["latest"])
        if int(step) not in {int(s) for s in idx["steps"]}:
            raise CheckpointMissingError(
                f"step {step} is not committed under {self.root!r} "
                f"(have: {sorted(int(s) for s in idx['steps'])})")
        return int(step)

    def load(self, like: Optional[PyTree] = None,
             step: Optional[int] = None) -> Tuple[Any, Dict]:
        """Load ``step`` (default: latest committed) -> (tree, meta).
        With ``like`` the payload is validated into that structure; with
        ``like=None`` the raw flat ``{leaf key: array}`` dict comes back."""
        step = self._resolve(step)
        p = self.path(step)
        if like is None:
            flat, manifest = load_arrays(p)
            return flat, manifest["meta"]
        tree = load_checkpoint(p, like)
        return tree, load_manifest(p)["meta"]


# ---------------------------------------------------------------------------
# full-FLState recovery points
# ---------------------------------------------------------------------------


def save_fl_checkpoint(mgr: CheckpointManager, step: int, state: PyTree, *,
                       run=None, ledger: Optional[Dict] = None,
                       history: Optional[List[Dict]] = None,
                       ef_bank: Optional[Dict[int, Tuple[int, np.ndarray]]] = None,
                       extra: Optional[Dict] = None) -> str:
    """One durable recovery point of a federated run at round ``step``.

    ``state`` is the complete engine ``FLState`` (params + N×d EF tree +
    staleness ring buffer + round counter) for the in-process path, or the
    bare params tree for the socket path. ``run`` (a ``RunConfig``)
    serializes the exact configuration including PRNG and fault seeds;
    ``ledger`` is the transport's ``LinkStats`` snapshot; ``history`` the
    live loop's per-round records; ``ef_bank`` maps client id ->
    (last committed round, flat f32 EF stream) — the slice a rejoining
    worker is re-synced from."""
    tree: Dict[str, Any] = {"state": state}
    meta: Dict[str, Any] = {"kind": "fl_state", "round": int(step)}
    if run is not None:
        meta["run"] = run.to_json()
    if ledger is not None:
        meta["ledger"] = ledger
    if history is not None:
        meta["history"] = history
    if ef_bank:
        tree["ef_bank"] = {str(c): np.asarray(v, np.float32)
                           for c, (_, v) in ef_bank.items()}
        meta["ef_bank_rounds"] = {str(c): int(r)
                                  for c, (r, _) in ef_bank.items()}
    if extra:
        meta.update(extra)
    return mgr.save(step, tree, meta)


def load_fl_checkpoint(mgr: CheckpointManager, like_state: PyTree,
                       step: Optional[int] = None,
                       ) -> Tuple[PyTree, Dict[int, Tuple[int, np.ndarray]], Dict]:
    """Load a recovery point -> (state, ef_bank, meta). ``like_state``
    fixes the state structure (validated, typed errors); the EF bank is
    read structure-free (its client-id key set is data, not schema)."""
    step = mgr._resolve(step)
    p = mgr.path(step)
    state = load_checkpoint(p, {"state": like_state})["state"]
    flat, manifest = load_arrays(p)
    meta = manifest["meta"]
    bank_rounds = meta.get("ef_bank_rounds", {})
    ef_bank = {int(c): (int(r), np.asarray(flat[f"ef_bank/{c}"], np.float32))
               for c, r in bank_rounds.items()}
    return state, ef_bank, meta
