"""Pytree checkpointing: flat .npz payload + JSON manifest of the treedef.

Keys are the '/'-joined path of each leaf; the manifest records tree
structure, shapes, and dtypes so loads are validated. Works for params,
optimizer state, EF residuals, FLState — any pytree of arrays.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


def _flatten_with_paths(tree: PyTree) -> Dict[str, np.ndarray]:
    out = {}

    def visit(path, leaf):
        key = "/".join(
            str(p.key) if isinstance(p, jax.tree_util.DictKey)
            else str(getattr(p, "idx", p)) for p in path) or "_root"
        arr = np.asarray(leaf)
        if arr.dtype not in (np.float32, np.float64, np.int32, np.int64,
                             np.uint32, np.uint8, np.int8, np.bool_,
                             np.float16, np.uint16, np.int16, np.uint64):
            arr = arr.astype(np.float32)      # bf16 etc: exact in f32
        out[key] = arr

    jax.tree_util.tree_map_with_path(visit, tree)
    return out


def save_checkpoint(path: str, tree: PyTree, meta: Dict = None) -> None:
    os.makedirs(path, exist_ok=True)
    flat = _flatten_with_paths(tree)
    np.savez(os.path.join(path, "arrays.npz"), **flat)
    manifest = {
        "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                   for k, v in flat.items()},
        "meta": meta or {},
    }
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)


def load_checkpoint(path: str, like: PyTree) -> PyTree:
    """Load into the structure of ``like`` (validates shapes/dtypes)."""
    data = np.load(os.path.join(path, "arrays.npz"))
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)

    def visit(p, leaf):
        key = "/".join(
            str(x.key) if isinstance(x, jax.tree_util.DictKey)
            else str(getattr(x, "idx", x)) for x in p) or "_root"
        arr = data[key]
        want = manifest["leaves"][key]
        assert list(arr.shape) == want["shape"], (key, arr.shape, want)
        assert tuple(arr.shape) == tuple(jnp.shape(leaf)), \
            f"{key}: ckpt {arr.shape} vs model {jnp.shape(leaf)}"
        return jnp.asarray(arr, dtype=jnp.result_type(leaf))

    return jax.tree_util.tree_map_with_path(visit, like)
